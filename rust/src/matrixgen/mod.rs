//! Synthetic sparse matrix generators.
//!
//! The paper evaluates on 22 matrices from the UF (SuiteSparse) collection
//! (Table 1). That collection is not available offline, so [`suite`]
//! regenerates each matrix *synthetically* to the same specification:
//! dimension `N`, non-zero count `NNZ`, mean `μ` and standard deviation `σ`
//! of non-zeros per row (hence the same `D_mat = σ/μ`), and a qualitative
//! structure class (banded FEM stencil, circuit with dense-row outliers,
//! power-tail, …).
//!
//! The auto-tuner's decision statistic only reads the row-length
//! distribution and the bandwidth structure, so matching those moments
//! exercises the same decision boundary as the originals.

pub mod rowlen;
pub mod suite;

pub use suite::{generate, measure, spec_by_name, table1_specs, GenClass, MatrixSpec};

use crate::formats::Csr;
use crate::rng::Rng;
use crate::{Index, Value};

/// How column positions are placed within a row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Clustered around the diagonal within a window ~3× the row length —
    /// FEM/stencil-like locality (good cache behaviour for `x`).
    Banded,
    /// Uniform over all columns — circuit/graph-like (poor locality).
    Uniform,
}

/// Uniform random CSR with Bernoulli density. Intended for tests; entries
/// are in `[-1, 1)`. Always places at least one entry when `density > 0`
/// and the matrix is non-empty... (no: may produce empty rows; that is a
/// feature, the kernels must handle them).
pub fn random_csr(rng: &mut Rng, n_rows: usize, n_cols: usize, density: f64) -> Csr {
    let mut triplets = Vec::new();
    for i in 0..n_rows {
        for j in 0..n_cols {
            if rng.next_bool(density) {
                triplets.push((i, j, rng.range_f64(-1.0, 1.0)));
            }
        }
    }
    Csr::from_triplets(n_rows, n_cols, &triplets).expect("in-bounds by construction")
}

/// Perfect circulant band matrix: every row has exactly `offsets.len()`
/// entries at `(i + off) mod n`. `D_mat = 0` — the ideal ELL case
/// ("ELL is compact if the matrix forms a perfect band", §4.5).
pub fn banded_circulant(rng: &mut Rng, n: usize, offsets: &[isize]) -> Csr {
    let mut triplets = Vec::with_capacity(n * offsets.len());
    for i in 0..n {
        for &off in offsets {
            let j = (i as isize + off).rem_euclid(n as isize) as usize;
            triplets.push((i, j, rng.range_f64(-1.0, 1.0)));
        }
    }
    Csr::from_triplets(n, n, &triplets).expect("in-bounds by construction")
}

/// Assemble a CSR matrix from a per-row length vector, placing columns
/// according to `placement`. Duplicate columns within a row are re-drawn,
/// so the resulting row lengths match `lens` exactly (capped at `n_cols`).
pub fn assemble_from_row_lens(
    rng: &mut Rng,
    n_cols: usize,
    lens: &[usize],
    placement: Placement,
) -> Csr {
    let n_rows = lens.len();
    let nnz: usize = lens.iter().map(|&l| l.min(n_cols)).sum();
    let mut row_ptr = Vec::with_capacity(n_rows + 1);
    let mut col_idx: Vec<Index> = Vec::with_capacity(nnz);
    let mut values: Vec<Value> = Vec::with_capacity(nnz);
    row_ptr.push(0usize);
    let mut scratch: Vec<usize> = Vec::new();
    for (i, &len_raw) in lens.iter().enumerate() {
        let len = len_raw.min(n_cols);
        scratch.clear();
        match placement {
            Placement::Uniform => {
                scratch.extend(rng.sample_indices(n_cols, len));
            }
            Placement::Banded => {
                // Window of width max(3*len, len) centred at the scaled
                // diagonal position, clipped to the matrix.
                let centre = if n_rows <= 1 {
                    0
                } else {
                    i * (n_cols - 1) / (n_rows - 1)
                };
                let w = (3 * len).max(len).max(1).min(n_cols);
                let lo = centre.saturating_sub(w / 2).min(n_cols - w);
                let picked = rng.sample_indices(w, len);
                scratch.extend(picked.into_iter().map(|p| lo + p));
                scratch.sort_unstable();
            }
        }
        debug_assert_eq!(scratch.len(), len);
        for &c in scratch.iter() {
            col_idx.push(c as Index);
            values.push(rng.range_f64(-1.0, 1.0));
        }
        // Uniform sample_indices returns sorted for the rejection path but
        // shuffled for the dense path — enforce sorted per CSR convention.
        let lo_off = *row_ptr.last().unwrap();
        let row_cols = &mut col_idx[lo_off..];
        if !row_cols.windows(2).all(|w| w[0] <= w[1]) {
            // sort cols and values together
            let mut pairs: Vec<(Index, Value)> = row_cols
                .iter()
                .copied()
                .zip(values[lo_off..].iter().copied())
                .collect();
            pairs.sort_unstable_by_key(|&(c, _)| c);
            for (k, (c, v)) in pairs.into_iter().enumerate() {
                col_idx[lo_off + k] = c;
                values[lo_off + k] = v;
            }
        }
        row_ptr.push(col_idx.len());
    }
    Csr::new(n_rows, n_cols, row_ptr, col_idx, values).expect("assembled CSR is valid")
}

/// Make a matrix symmetric-positive-definite-ish for solver tests: returns
/// `A + Aᵀ + diag(shift)` where `shift` exceeds the max row sum, giving a
/// strictly diagonally dominant (hence SPD for symmetric) system.
pub fn make_spd(a: &Csr) -> Csr {
    use crate::formats::SparseMatrix as _;
    assert_eq!(a.n_rows(), a.n_cols(), "make_spd needs a square matrix");
    let n = a.n_rows();
    let at = a.transpose();
    let mut triplets = a.to_triplets();
    triplets.extend(at.to_triplets());
    // Row sums of |A + Aᵀ| to size the diagonal shift.
    let sym = Csr::from_triplets(n, n, &triplets).unwrap();
    let mut max_row_sum: Value = 0.0;
    for i in 0..n {
        let s: Value = sym.row(i).map(|(_, v)| v.abs()).sum();
        max_row_sum = max_row_sum.max(s);
    }
    let shift = max_row_sum + 1.0;
    let mut t = sym.to_triplets();
    for i in 0..n {
        t.push((i, i, shift));
    }
    Csr::from_triplets(n, n, &t).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::SparseMatrix;

    #[test]
    fn random_csr_density_ballpark() {
        let mut rng = Rng::new(1);
        let a = random_csr(&mut rng, 100, 100, 0.1);
        let d = a.nnz() as f64 / 10_000.0;
        assert!((0.07..0.13).contains(&d), "density {d}");
    }

    #[test]
    fn banded_has_zero_dmat() {
        let mut rng = Rng::new(2);
        let a = banded_circulant(&mut rng, 50, &[-1, 0, 1]);
        assert_eq!(a.nnz(), 150);
        for i in 0..50 {
            assert_eq!(a.row_len(i), 3);
        }
    }

    #[test]
    fn assemble_exact_row_lengths() {
        let mut rng = Rng::new(3);
        let lens = vec![3usize, 0, 7, 1, 4];
        for placement in [Placement::Banded, Placement::Uniform] {
            let a = assemble_from_row_lens(&mut rng, 40, &lens, placement);
            for (i, &l) in lens.iter().enumerate() {
                assert_eq!(a.row_len(i), l, "row {i} {placement:?}");
            }
            a.validate().unwrap();
            // Columns sorted & unique within rows.
            for i in 0..lens.len() {
                let cols: Vec<_> = a.row(i).map(|(c, _)| c).collect();
                let mut s = cols.clone();
                s.sort_unstable();
                s.dedup();
                assert_eq!(cols, s, "row {i} not sorted/unique");
            }
        }
    }

    #[test]
    fn assemble_caps_at_ncols() {
        let mut rng = Rng::new(4);
        let a = assemble_from_row_lens(&mut rng, 5, &[9], Placement::Uniform);
        assert_eq!(a.row_len(0), 5);
    }

    #[test]
    fn banded_placement_is_local() {
        let mut rng = Rng::new(5);
        let lens = vec![5usize; 200];
        let a = assemble_from_row_lens(&mut rng, 200, &lens, Placement::Banded);
        for i in 0..200 {
            for (c, _) in a.row(i) {
                let d = (c as isize - i as isize).abs();
                assert!(d <= 20, "row {i} col {c} too far from diagonal");
            }
        }
    }

    #[test]
    fn make_spd_is_symmetric_dominant() {
        let mut rng = Rng::new(6);
        let a = random_csr(&mut rng, 30, 30, 0.1);
        let s = make_spd(&a);
        let st = s.transpose();
        assert_eq!(s, st, "not symmetric");
        for i in 0..30 {
            let diag = s.row(i).find(|&(c, _)| c as usize == i).map(|(_, v)| v).unwrap();
            let off: f64 = s.row(i).filter(|&(c, _)| c as usize != i).map(|(_, v)| v.abs()).sum();
            assert!(diag > off, "row {i} not dominant: {diag} <= {off}");
        }
    }
}
