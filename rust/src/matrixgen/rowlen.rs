//! Row-length distribution synthesis.
//!
//! Given targets `(n, nnz, μ, σ)` from Table 1, produce a vector of `n` row
//! lengths whose sum is exactly `nnz` and whose sample standard deviation
//! approximates `σ`. Two regimes:
//!
//! * **Low variation** (`σ/μ` small — chem_master, wang3, epb1, …):
//!   a clamped rounded normal, then a repair pass that nudges random rows
//!   by ±1 until the sum is exact (preserving σ to first order).
//! * **Heavy tail** (`σ/μ` large — memplus, torso1, viscoplastic2):
//!   a two-point mixture: `n·p` outlier rows of length `b + d` over a base
//!   of length ≈ `b`. Moment matching gives `d = (σ² + m₁²)/m₁`,
//!   `p = m₁ / d` with `m₁ = μ − b`, which reproduces both moments exactly
//!   in expectation (`Var = p·d² − (p·d)²= m₁·d − m₁²`).

use crate::rng::Rng;

/// Sample statistics of a row-length vector.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LenStats {
    /// Arithmetic mean μ.
    pub mean: f64,
    /// Population standard deviation σ.
    pub std: f64,
    /// Maximum length (the ELL bandwidth this vector implies).
    pub max: usize,
    /// Total (= nnz).
    pub sum: usize,
}

/// Compute [`LenStats`] for a length vector.
pub fn stats(lens: &[usize]) -> LenStats {
    let n = lens.len().max(1) as f64;
    let sum: usize = lens.iter().sum();
    let mean = sum as f64 / n;
    let var = lens.iter().map(|&l| (l as f64 - mean).powi(2)).sum::<f64>() / n;
    LenStats { mean, std: var.sqrt(), max: lens.iter().copied().max().unwrap_or(0), sum }
}

/// [`LenStats`] straight from a CSR row-pointer array, without
/// materialising the length vector — the planner's partition-strategy
/// pick reads the `max`/`mean` skew from here on every plan build.
pub fn stats_of_row_ptr(row_ptr: &[usize]) -> LenStats {
    let n = row_ptr.len().saturating_sub(1);
    let nf = n.max(1) as f64;
    let sum = if n == 0 { 0 } else { row_ptr[n] };
    let mean = sum as f64 / nf;
    let mut var = 0.0;
    let mut max = 0usize;
    for i in 0..n {
        let l = row_ptr[i + 1] - row_ptr[i];
        var += (l as f64 - mean).powi(2);
        max = max.max(l);
    }
    LenStats { mean, std: (var / nf).sqrt(), max, sum }
}

/// Synthesize `n` row lengths with total exactly `nnz` and standard
/// deviation approximately `sigma`. `max_cols` caps individual lengths.
pub fn synthesize(rng: &mut Rng, n: usize, nnz: usize, sigma: f64, max_cols: usize) -> Vec<usize> {
    synthesize_with_max(rng, n, nnz, sigma, max_cols, None)
}

/// Like [`synthesize`], but when `target_max` is given the heavy-tail
/// mixture is solved so the longest rows land near that bandwidth (the
/// published max-row of the original UF matrix), pinning the ELL fill
/// ratio as well as σ. With base length `b`, outlier excess `d = max − b`
/// and rate `p = (μ−b)/d`, the variance is `(μ−b)·d − (μ−b)²`; requiring
/// it to equal σ² gives `b = μ − σ²/(max − μ)`.
pub fn synthesize_with_max(
    rng: &mut Rng,
    n: usize,
    nnz: usize,
    sigma: f64,
    max_cols: usize,
    target_max: Option<usize>,
) -> Vec<usize> {
    if n == 0 {
        return Vec::new();
    }
    let mu = nnz as f64 / n as f64;
    let mut lens = match target_max {
        Some(m) if (m as f64) > mu + sigma => {
            synth_heavy_tail_pinned(rng, n, mu, sigma, max_cols, m as f64)
        }
        _ if sigma <= mu * 0.75 => synth_normal(rng, n, mu, sigma, max_cols),
        _ => synth_heavy_tail(rng, n, mu, sigma, max_cols),
    };
    repair_sum(rng, &mut lens, nnz, max_cols);
    lens
}

/// Two-point mixture with the outlier length pinned at `target_max`.
fn synth_heavy_tail_pinned(
    rng: &mut Rng,
    n: usize,
    mu: f64,
    sigma: f64,
    max_cols: usize,
    target_max: f64,
) -> Vec<usize> {
    let target_max = target_max.min(max_cols as f64);
    // b = mu - sigma^2/(max - mu), clamped to at least 1.
    let b = (mu - sigma * sigma / (target_max - mu)).max(1.0);
    let m1 = (mu - b).max(1e-3);
    let d = (target_max - b).max(1.0);
    let p = (m1 / d).clamp(0.0, 0.5);
    let n_out = ((n as f64 * p).round() as usize).clamp(1, n / 2 + 1);
    let mut lens: Vec<usize> = (0..n)
        .map(|_| rng.next_rounded_normal(b, (b * 0.1).max(0.5)).clamp(1, max_cols))
        .collect();
    for idx in rng.sample_indices(n, n_out) {
        // Tight jitter so the bandwidth stays near the published max.
        let l = rng.next_rounded_normal(target_max, target_max * 0.03);
        lens[idx] = l.clamp(1, max_cols);
    }
    lens
}

/// Clamped rounded normal draw.
fn synth_normal(rng: &mut Rng, n: usize, mu: f64, sigma: f64, max_cols: usize) -> Vec<usize> {
    (0..n)
        .map(|_| rng.next_rounded_normal(mu, sigma).min(max_cols))
        .collect()
}

/// Two-point mixture for heavy-tailed targets (memplus/torso1-like).
fn synth_heavy_tail(rng: &mut Rng, n: usize, mu: f64, sigma: f64, max_cols: usize) -> Vec<usize> {
    // Base length: most rows are short. Use half the mean, at least 1.
    let b = (mu * 0.5).max(1.0).floor();
    let m1 = (mu - b).max(0.5);
    let d = (sigma * sigma + m1 * m1) / m1;
    let p = (m1 / d).clamp(0.0, 0.5);
    let n_out = ((n as f64 * p).round() as usize).clamp(1, n / 2 + 1);
    let out_len = ((b + d).round() as usize).min(max_cols).max(1);
    let mut lens: Vec<usize> = (0..n)
        .map(|_| {
            // Small jitter on the base so it isn't a delta spike.
            let jitter = rng.next_rounded_normal(b, (b * 0.2).max(0.5));
            jitter.clamp(1, max_cols)
        })
        .collect();
    for idx in rng.sample_indices(n, n_out) {
        // Jitter outlier lengths ±20% so the tail isn't a single atom.
        let l = rng.next_rounded_normal(out_len as f64, out_len as f64 * 0.2);
        lens[idx] = l.clamp(1, max_cols);
    }
    lens
}

/// Nudge random rows by ±1 until `sum(lens) == nnz`. Rows at 0 or
/// `max_cols` are skipped, so termination is guaranteed for feasible
/// targets (`nnz ≤ n · max_cols`).
fn repair_sum(rng: &mut Rng, lens: &mut [usize], nnz: usize, max_cols: usize) {
    assert!(
        nnz <= lens.len() * max_cols,
        "infeasible target: nnz={nnz} > n*max_cols={}",
        lens.len() * max_cols
    );
    let mut sum: usize = lens.iter().sum();
    let n = lens.len();
    let mut stall = 0usize;
    while sum != nnz && stall < 100 * n + 1000 {
        let i = rng.range(0, n);
        if sum < nnz && lens[i] < max_cols {
            lens[i] += 1;
            sum += 1;
        } else if sum > nnz && lens[i] > 0 {
            lens[i] -= 1;
            sum -= 1;
        } else {
            stall += 1;
            continue;
        }
        stall = 0;
    }
    // Deterministic fallback sweep for pathological cases.
    if sum != nnz {
        for l in lens.iter_mut() {
            while sum < nnz && *l < max_cols {
                *l += 1;
                sum += 1;
            }
            while sum > nnz && *l > 0 {
                *l -= 1;
                sum -= 1;
            }
        }
    }
    debug_assert_eq!(sum, nnz);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_sum_low_variance() {
        let mut rng = Rng::new(10);
        let lens = synthesize(&mut rng, 10_000, 49_800, 0.14, 10_000);
        let s = stats(&lens);
        assert_eq!(s.sum, 49_800);
        assert!((s.mean - 4.98).abs() < 0.01);
        // σ target 0.14 is tiny; allow generous but bounded slack.
        assert!(s.std < 0.6, "std {}", s.std);
    }

    #[test]
    fn heavy_tail_matches_moments() {
        // memplus: n=17758, nnz=126150, mu=7.10, sigma=22.03.
        let mut rng = Rng::new(11);
        let lens = synthesize(&mut rng, 17_758, 126_150, 22.03, 17_758);
        let s = stats(&lens);
        assert_eq!(s.sum, 126_150);
        assert!((s.mean - 7.10).abs() < 0.02, "mean {}", s.mean);
        let dmat = s.std / s.mean;
        assert!((2.0..4.5).contains(&dmat), "D_mat {dmat} target 3.10");
        assert!(s.max > 100, "tail too short: max {}", s.max);
    }

    #[test]
    fn extreme_tail_torso1_like() {
        // torso1 scaled 1/10: n=11616, nnz=851650, mu=73.3, sigma=419.6.
        let mut rng = Rng::new(12);
        let lens = synthesize(&mut rng, 11_616, 851_650, 419.58, 11_616);
        let s = stats(&lens);
        assert_eq!(s.sum, 851_650);
        let dmat = s.std / s.mean;
        assert!((3.5..8.5).contains(&dmat), "D_mat {dmat} target 5.72");
    }

    #[test]
    fn moderate_sigma_regime() {
        // ex19: mu=21.64 sigma=12.28 (sigma/mu = 0.57 -> normal regime).
        let mut rng = Rng::new(13);
        let lens = synthesize(&mut rng, 12_005, 259_879, 12.28, 12_005);
        let s = stats(&lens);
        assert_eq!(s.sum, 259_879);
        let dmat = s.std / s.mean;
        assert!((0.4..0.75).contains(&dmat), "D_mat {dmat} target 0.56");
    }

    #[test]
    fn feasibility_assertion() {
        let mut rng = Rng::new(14);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            synthesize(&mut rng, 2, 100, 1.0, 3)
        }));
        assert!(r.is_err(), "infeasible target must panic");
    }

    #[test]
    fn zero_rows() {
        let mut rng = Rng::new(15);
        assert!(synthesize(&mut rng, 0, 0, 0.0, 10).is_empty());
    }

    #[test]
    fn stats_of_constant_vector() {
        let s = stats(&[4, 4, 4, 4]);
        assert_eq!(s.mean, 4.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.max, 4);
        assert_eq!(s.sum, 16);
    }

    #[test]
    fn stats_of_row_ptr_matches_stats_of_lens() {
        let lens = [3usize, 0, 7, 1, 0, 12];
        let mut row_ptr = vec![0usize];
        for &l in &lens {
            row_ptr.push(row_ptr.last().unwrap() + l);
        }
        assert_eq!(stats_of_row_ptr(&row_ptr), stats(&lens));
        // Degenerate row_ptr shapes.
        assert_eq!(stats_of_row_ptr(&[0]).sum, 0);
        assert_eq!(stats_of_row_ptr(&[0]).max, 0);
        assert_eq!(stats_of_row_ptr(&[0, 0, 0]), stats(&[0, 0]));
    }
}
