//! Conjugate Gradient for SPD systems — the canonical SpMV-bound iterative
//! solver the paper's amortisation argument targets.

use super::{axpy, dot, norm2, xpby, SolveStats, SolverOptions, SpmvOp};
use crate::{Result, Value};

/// Solve `A·x = b` with (unpreconditioned) CG. `x` carries the initial
/// guess in and the solution out.
pub fn cg<Op: SpmvOp + ?Sized>(
    a: &mut Op,
    b: &[Value],
    x: &mut [Value],
    opts: &SolverOptions,
) -> Result<SolveStats> {
    let n = a.n();
    anyhow::ensure!(b.len() == n && x.len() == n, "dimension mismatch");
    let bnorm = norm2(b).max(f64::MIN_POSITIVE);
    let mut spmv_calls = 0usize;

    // r = b - A x0
    let mut r = vec![0.0; n];
    a.apply(x, &mut r)?;
    spmv_calls += 1;
    for i in 0..n {
        r[i] = b[i] - r[i];
    }
    let mut p = r.clone();
    let mut ap = vec![0.0; n];
    let mut rr = dot(&r, &r);

    for k in 0..opts.max_iters {
        if rr.sqrt() / bnorm <= opts.tol {
            return Ok(SolveStats {
                iterations: k,
                residual: rr.sqrt(),
                converged: true,
                spmv_calls,
                ..Default::default()
            });
        }
        a.apply(&p, &mut ap)?;
        spmv_calls += 1;
        let pap = dot(&p, &ap);
        anyhow::ensure!(
            pap > 0.0,
            "CG breakdown: p·Ap = {pap} ≤ 0 (matrix not SPD?)"
        );
        let alpha = rr / pap;
        axpy(alpha, &p, x);
        axpy(-alpha, &ap, &mut r);
        let rr_new = dot(&r, &r);
        let beta = rr_new / rr;
        xpby(&r, beta, &mut p);
        rr = rr_new;
    }
    Ok(SolveStats {
        iterations: opts.max_iters,
        residual: rr.sqrt(),
        converged: rr.sqrt() / bnorm <= opts.tol,
        spmv_calls,
        ..Default::default()
    })
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{assert_solution, spd_system};
    use super::*;
    use crate::autotune::atlib::Durmv;
    use crate::autotune::online::TuningData;
    use crate::autotune::MemoryPolicy;
    use crate::spmv::Implementation;

    #[test]
    fn cg_solves_spd_system() {
        let (mut a, b, x_true) = spd_system(1, 120);
        let mut x = vec![0.0; 120];
        let stats = cg(&mut a, &b, &mut x, &SolverOptions::default()).unwrap();
        assert!(stats.converged, "residual {}", stats.residual);
        assert_solution(&x, &x_true, 1e-6);
        assert!(stats.spmv_calls >= stats.iterations);
    }

    #[test]
    fn cg_through_autotuned_handle() {
        let (a, b, x_true) = spd_system(2, 80);
        let tuning = TuningData {
            backend: "sim:ES2".into(),
            imp: Implementation::EllRowOuter,
            threads: 1,
            c: 1.0,
            d_star: Some(3.1),
        };
        let mut h = Durmv::new(a, tuning, MemoryPolicy::unlimited(), 2);
        let mut x = vec![0.0; 80];
        let stats = cg(&mut h, &b, &mut x, &SolverOptions::default()).unwrap();
        assert!(stats.converged);
        assert_solution(&x, &x_true, 1e-6);
        // The AT handle served every SpMV and transformed at most once.
        assert_eq!(h.calls as usize, stats.spmv_calls);
    }

    #[test]
    fn cg_zero_rhs_converges_immediately() {
        let (mut a, _, _) = spd_system(3, 40);
        let b = vec![0.0; 40];
        let mut x = vec![0.0; 40];
        let stats = cg(&mut a, &b, &mut x, &SolverOptions::default()).unwrap();
        assert!(stats.converged);
        assert_eq!(stats.iterations, 0);
    }

    #[test]
    fn cg_respects_iteration_cap() {
        let (mut a, b, _) = spd_system(4, 100);
        let mut x = vec![0.0; 100];
        let opts = SolverOptions { tol: 1e-300, max_iters: 3 };
        let stats = cg(&mut a, &b, &mut x, &opts).unwrap();
        assert_eq!(stats.iterations, 3);
        assert!(!stats.converged);
    }

    #[test]
    fn cg_rejects_dimension_mismatch() {
        let (mut a, _, _) = spd_system(5, 10);
        let b = vec![0.0; 9];
        let mut x = vec![0.0; 10];
        assert!(cg(&mut a, &b, &mut x, &SolverOptions::default()).is_err());
    }
}
