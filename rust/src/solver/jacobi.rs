//! Weighted Jacobi iteration — the simplest SpMV-per-step solver; converges
//! for strictly diagonally dominant systems (which
//! [`crate::matrixgen::make_spd`] produces).

use super::{norm2, SolveStats, SolverOptions, SpmvOp};
use crate::{Result, Value};

/// Solve `A·x = b` with damped Jacobi: `x ← x + ω·D⁻¹·(b − A·x)`.
pub fn jacobi<Op: SpmvOp + ?Sized>(
    a: &mut Op,
    b: &[Value],
    x: &mut [Value],
    omega: f64,
    opts: &SolverOptions,
) -> Result<SolveStats> {
    let n = a.n();
    anyhow::ensure!(b.len() == n && x.len() == n, "dimension mismatch");
    anyhow::ensure!(omega > 0.0 && omega <= 1.0, "omega must be in (0,1], got {omega}");
    let d = a.diagonal()?;
    anyhow::ensure!(
        d.iter().all(|&v| v != 0.0),
        "Jacobi needs a zero-free diagonal"
    );
    let bnorm = norm2(b).max(f64::MIN_POSITIVE);
    let mut ax = vec![0.0; n];
    let mut spmv_calls = 0usize;
    for k in 0..opts.max_iters {
        a.apply(x, &mut ax)?;
        spmv_calls += 1;
        let mut rnorm2 = 0.0;
        for i in 0..n {
            let r = b[i] - ax[i];
            rnorm2 += r * r;
            x[i] += omega * r / d[i];
        }
        let rel = rnorm2.sqrt() / bnorm;
        if rel <= opts.tol {
            return Ok(SolveStats {
                iterations: k + 1,
                residual: rnorm2.sqrt(),
                converged: true,
                spmv_calls,
                ..Default::default()
            });
        }
    }
    // Final residual check.
    a.apply(x, &mut ax)?;
    spmv_calls += 1;
    let res: f64 = (0..n).map(|i| (b[i] - ax[i]).powi(2)).sum::<f64>().sqrt();
    Ok(SolveStats {
        iterations: opts.max_iters,
        residual: res,
        converged: res / bnorm <= opts.tol,
        spmv_calls,
        ..Default::default()
    })
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{assert_solution, spd_system};
    use super::*;

    #[test]
    fn jacobi_converges_on_dominant_system() {
        let (mut a, b, x_true) = spd_system(11, 60);
        let mut x = vec![0.0; 60];
        let opts = SolverOptions { tol: 1e-10, max_iters: 5000 };
        let stats = jacobi(&mut a, &b, &mut x, 1.0, &opts).unwrap();
        assert!(stats.converged, "residual {}", stats.residual);
        assert_solution(&x, &x_true, 1e-7);
    }

    #[test]
    fn jacobi_rejects_zero_diagonal() {
        use crate::formats::Csr;
        let mut a = Csr::from_triplets(2, 2, &[(0, 1, 1.0), (1, 0, 1.0)]).unwrap();
        let b = vec![1.0, 1.0];
        let mut x = vec![0.0; 2];
        assert!(jacobi(&mut a, &b, &mut x, 1.0, &SolverOptions::default()).is_err());
    }

    #[test]
    fn jacobi_rejects_bad_omega() {
        let (mut a, b, _) = spd_system(12, 10);
        let mut x = vec![0.0; 10];
        assert!(jacobi(&mut a, &b, &mut x, 0.0, &SolverOptions::default()).is_err());
        assert!(jacobi(&mut a, &b, &mut x, 1.5, &SolverOptions::default()).is_err());
    }
}
