//! Jacobi-preconditioned Conjugate Gradient — the solver shape OpenATLib's
//! users actually run (diagonal scaling is the default preconditioner for
//! the FEM/device matrices of Table 1). Completes the §2.2 amortisation
//! story: preconditioning reduces iteration counts, which *tightens* the
//! budget the transformation must amortise within.

use super::{axpy, dot, norm2, SolveStats, SolverOptions, SpmvOp};
use crate::{Result, Value};

/// Solve `A·x = b` with CG preconditioned by `M = diag(A)`.
pub fn pcg<Op: SpmvOp + ?Sized>(
    a: &mut Op,
    b: &[Value],
    x: &mut [Value],
    opts: &SolverOptions,
) -> Result<SolveStats> {
    let n = a.n();
    anyhow::ensure!(b.len() == n && x.len() == n, "dimension mismatch");
    let d = a.diagonal()?;
    anyhow::ensure!(
        d.iter().all(|&v| v != 0.0),
        "Jacobi preconditioner needs a zero-free diagonal"
    );
    let minv: Vec<Value> = d.iter().map(|&v| 1.0 / v).collect();
    let bnorm = norm2(b).max(f64::MIN_POSITIVE);
    let mut spmv_calls = 0usize;

    let mut r = vec![0.0; n];
    a.apply(x, &mut r)?;
    spmv_calls += 1;
    for i in 0..n {
        r[i] = b[i] - r[i];
    }
    let mut z: Vec<Value> = r.iter().zip(&minv).map(|(ri, mi)| ri * mi).collect();
    let mut p = z.clone();
    let mut ap = vec![0.0; n];
    let mut rz = dot(&r, &z);

    for k in 0..opts.max_iters {
        let res = norm2(&r);
        if res / bnorm <= opts.tol {
            return Ok(SolveStats { iterations: k, residual: res, converged: true, spmv_calls });
        }
        a.apply(&p, &mut ap)?;
        spmv_calls += 1;
        let pap = dot(&p, &ap);
        anyhow::ensure!(pap > 0.0, "PCG breakdown: p·Ap = {pap} ≤ 0 (matrix not SPD?)");
        let alpha = rz / pap;
        axpy(alpha, &p, x);
        axpy(-alpha, &ap, &mut r);
        for i in 0..n {
            z[i] = r[i] * minv[i];
        }
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
        rz = rz_new;
    }
    let res = norm2(&r);
    Ok(SolveStats {
        iterations: opts.max_iters,
        residual: res,
        converged: res / bnorm <= opts.tol,
        spmv_calls,
    })
}

#[cfg(test)]
mod tests {
    use super::super::cg::cg;
    use super::super::testutil::{assert_solution, spd_system};
    use super::*;
    use crate::formats::Csr;
    use crate::formats::SparseMatrix as _;
    use crate::matrixgen::make_spd;
    use crate::rng::Rng;

    #[test]
    fn pcg_solves_spd_system() {
        let (mut a, b, x_true) = spd_system(51, 120);
        let mut x = vec![0.0; 120];
        let stats = pcg(&mut a, &b, &mut x, &SolverOptions::default()).unwrap();
        assert!(stats.converged, "residual {}", stats.residual);
        assert_solution(&x, &x_true, 1e-6);
    }

    #[test]
    fn preconditioning_helps_on_badly_scaled_systems() {
        // Wildly varying diagonal: plain CG crawls, Jacobi-PCG fixes the
        // conditioning.
        let mut rng = Rng::new(52);
        let n = 150;
        let base = make_spd(&crate::matrixgen::random_csr(&mut rng, n, n, 0.05));
        let mut t = base.to_triplets();
        for i in 0..n {
            // Scale row+col i by 10^(i mod 4) through an extra diagonal term.
            let s = 10f64.powi((i % 4) as i32 * 2);
            t.push((i, i, s));
        }
        let a = Csr::from_triplets(n, n, &t).unwrap();
        let x_true: Vec<f64> = (0..n).map(|i| ((i + 1) as f64 * 0.07).sin()).collect();
        let mut b = vec![0.0; n];
        a.spmv(&x_true, &mut b);

        let opts = SolverOptions { tol: 1e-10, max_iters: 3000 };
        let mut a1 = a.clone();
        let mut x1 = vec![0.0; n];
        let plain = cg(&mut a1, &b, &mut x1, &opts).unwrap();
        let mut a2 = a.clone();
        let mut x2 = vec![0.0; n];
        let pre = pcg(&mut a2, &b, &mut x2, &opts).unwrap();
        assert!(pre.converged);
        assert_solution(&x2, &x_true, 1e-6);
        assert!(
            pre.iterations < plain.iterations,
            "PCG {} should beat CG {} on this system",
            pre.iterations,
            plain.iterations
        );
    }

    #[test]
    fn pcg_rejects_zero_diagonal() {
        let mut a = Csr::from_triplets(2, 2, &[(0, 1, 1.0), (1, 0, 1.0)]).unwrap();
        let b = vec![1.0, 1.0];
        let mut x = vec![0.0; 2];
        assert!(pcg(&mut a, &b, &mut x, &SolverOptions::default()).is_err());
    }

    #[test]
    fn pcg_zero_rhs() {
        let (mut a, _, _) = spd_system(53, 30);
        let b = vec![0.0; 30];
        let mut x = vec![0.0; 30];
        let stats = pcg(&mut a, &b, &mut x, &SolverOptions::default()).unwrap();
        assert!(stats.converged);
        assert_eq!(stats.iterations, 0);
    }
}
