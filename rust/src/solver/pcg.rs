//! Preconditioned Conjugate Gradient — the solver shape OpenATLib's
//! users actually run (diagonal scaling is the default preconditioner for
//! the FEM/device matrices of Table 1). Completes the §2.2 amortisation
//! story: preconditioning reduces iteration counts, which *tightens* the
//! budget the transformation must amortise within.
//!
//! [`pcg_with`] is the general form: it applies any
//! [`Preconditioner`] — [`Identity`](crate::precond::Identity),
//! [`Jacobi`], or the level-scheduled
//! [`SymGs`](crate::precond::SymGs) — and counts preconditioner work
//! (`precond_calls`, `precond_setup_seconds`) alongside `spmv_calls` so
//! the amortisation denominator covers the whole iteration, not just
//! the SpMV half. [`pcg`] is the historical Jacobi instantiation: same
//! signature, same semantics, same failure on zero diagonals — but the
//! diagonal extraction and inversion now happen once, behind the trait,
//! instead of being rescanned on every solve call.

use super::{axpy, dot, norm2, SolveStats, SolverOptions, SpmvOp};
use crate::precond::{Jacobi, Preconditioner};
use crate::{Result, Value};

/// Solve `A·x = b` with CG preconditioned by `M = diag(A)` (the
/// [`Jacobi`] instantiation of [`pcg_with`]).
pub fn pcg<Op: SpmvOp + ?Sized>(
    a: &mut Op,
    b: &[Value],
    x: &mut [Value],
    opts: &SolverOptions,
) -> Result<SolveStats> {
    let mut m = Jacobi::from_diagonal(a.diagonal()?)?;
    pcg_with(a, &mut m, b, x, opts)
}

/// Solve `A·x = b` with CG preconditioned by `m`.
///
/// `m` is applied once to the initial residual and once per iteration;
/// each application is counted in [`SolveStats::precond_calls`], and
/// `m`'s one-time setup cost is reported in
/// [`SolveStats::precond_setup_seconds`] (whether it was paid by this
/// call or amortised from a coordinator cache).
pub fn pcg_with<Op: SpmvOp + ?Sized>(
    a: &mut Op,
    m: &mut dyn Preconditioner,
    b: &[Value],
    x: &mut [Value],
    opts: &SolverOptions,
) -> Result<SolveStats> {
    let n = a.n();
    anyhow::ensure!(b.len() == n && x.len() == n, "dimension mismatch");
    let bnorm = norm2(b).max(f64::MIN_POSITIVE);
    let mut spmv_calls = 0usize;
    let mut precond_calls = 0usize;
    let setup_seconds = m.setup_seconds();
    let stats_of = move |iterations, residual: f64, converged, spmv_calls, precond_calls| {
        SolveStats {
            iterations,
            residual,
            converged,
            spmv_calls,
            precond_calls,
            precond_setup_seconds: setup_seconds,
        }
    };

    let mut r = vec![0.0; n];
    a.apply(x, &mut r)?;
    spmv_calls += 1;
    for i in 0..n {
        r[i] = b[i] - r[i];
    }
    let mut z = vec![0.0; n];
    m.apply(&r, &mut z);
    precond_calls += 1;
    let mut p = z.clone();
    let mut ap = vec![0.0; n];
    let mut rz = dot(&r, &z);

    for k in 0..opts.max_iters {
        let res = norm2(&r);
        if res / bnorm <= opts.tol {
            return Ok(stats_of(k, res, true, spmv_calls, precond_calls));
        }
        a.apply(&p, &mut ap)?;
        spmv_calls += 1;
        let pap = dot(&p, &ap);
        anyhow::ensure!(pap > 0.0, "PCG breakdown: p·Ap = {pap} ≤ 0 (matrix not SPD?)");
        let alpha = rz / pap;
        axpy(alpha, &p, x);
        axpy(-alpha, &ap, &mut r);
        m.apply(&r, &mut z);
        precond_calls += 1;
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
        rz = rz_new;
    }
    let res = norm2(&r);
    let converged = res / bnorm <= opts.tol;
    Ok(stats_of(opts.max_iters, res, converged, spmv_calls, precond_calls))
}

#[cfg(test)]
mod tests {
    use super::super::cg::cg;
    use super::super::testutil::{assert_solution, spd_system};
    use super::*;
    use crate::formats::Csr;
    use crate::formats::SparseMatrix as _;
    use crate::matrixgen::make_spd;
    use crate::precond::Identity;
    use crate::rng::Rng;

    #[test]
    fn pcg_solves_spd_system() {
        let (mut a, b, x_true) = spd_system(51, 120);
        let mut x = vec![0.0; 120];
        let stats = pcg(&mut a, &b, &mut x, &SolverOptions::default()).unwrap();
        assert!(stats.converged, "residual {}", stats.residual);
        assert_solution(&x, &x_true, 1e-6);
        // One initial apply plus one per iteration, and the Jacobi setup
        // cost is surfaced.
        assert_eq!(stats.precond_calls, stats.iterations + 1);
        assert!(stats.precond_setup_seconds >= 0.0);
    }

    #[test]
    fn preconditioning_helps_on_badly_scaled_systems() {
        // Wildly varying diagonal: plain CG crawls, Jacobi-PCG fixes the
        // conditioning.
        let mut rng = Rng::new(52);
        let n = 150;
        let base = make_spd(&crate::matrixgen::random_csr(&mut rng, n, n, 0.05));
        let mut t = base.to_triplets();
        for i in 0..n {
            // Scale row+col i by 10^(i mod 4) through an extra diagonal term.
            let s = 10f64.powi((i % 4) as i32 * 2);
            t.push((i, i, s));
        }
        let a = Csr::from_triplets(n, n, &t).unwrap();
        let x_true: Vec<f64> = (0..n).map(|i| ((i + 1) as f64 * 0.07).sin()).collect();
        let mut b = vec![0.0; n];
        a.spmv(&x_true, &mut b);

        let opts = SolverOptions { tol: 1e-10, max_iters: 3000 };
        let mut a1 = a.clone();
        let mut x1 = vec![0.0; n];
        let plain = cg(&mut a1, &b, &mut x1, &opts).unwrap();
        let mut a2 = a.clone();
        let mut x2 = vec![0.0; n];
        let pre = pcg(&mut a2, &b, &mut x2, &opts).unwrap();
        assert!(pre.converged);
        assert_solution(&x2, &x_true, 1e-6);
        assert!(
            pre.iterations < plain.iterations,
            "PCG {} should beat CG {} on this system",
            pre.iterations,
            plain.iterations
        );
    }

    #[test]
    fn pcg_with_identity_matches_plain_cg_iterations() {
        let (mut a, b, x_true) = spd_system(54, 90);
        let mut a2 = a.clone();
        let mut x_cg = vec![0.0; 90];
        let plain = cg(&mut a2, &b, &mut x_cg, &SolverOptions::default()).unwrap();
        let mut x = vec![0.0; 90];
        let ident = pcg_with(&mut a, &mut Identity, &b, &mut x, &SolverOptions::default())
            .unwrap();
        assert!(ident.converged);
        assert_solution(&x, &x_true, 1e-6);
        // Identity preconditioning is CG: same Krylov space, same count.
        assert_eq!(ident.iterations, plain.iterations);
        assert_eq!(ident.precond_setup_seconds, 0.0);
    }

    #[test]
    fn pcg_rejects_zero_diagonal() {
        let mut a = Csr::from_triplets(2, 2, &[(0, 1, 1.0), (1, 0, 1.0)]).unwrap();
        let b = vec![1.0, 1.0];
        let mut x = vec![0.0; 2];
        assert!(pcg(&mut a, &b, &mut x, &SolverOptions::default()).is_err());
    }

    #[test]
    fn pcg_zero_rhs() {
        let (mut a, _, _) = spd_system(53, 30);
        let b = vec![0.0; 30];
        let mut x = vec![0.0; 30];
        let stats = pcg(&mut a, &b, &mut x, &SolverOptions::default()).unwrap();
        assert!(stats.converged);
        assert_eq!(stats.iterations, 0);
    }
}
