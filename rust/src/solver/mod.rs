//! Iterative solvers driving AT-routed SpMV.
//!
//! The paper motivates run-time transformation by iterative solvers: the
//! §2.2 discussion prices the transformation in SpMV iterations ("2–100
//! times … achievable for many iterative solvers"). These solvers call
//! SpMV through a [`SpmvOp`] abstraction so a cached
//! [`crate::spmv::SpmvPlan`] (the preferred operator: one transformation,
//! one partition, a persistent pool), the auto-tuned
//! [`crate::autotune::atlib::Durmv`] handle, or a plain CSR can sit
//! underneath, and the break-even analysis of [`crate::autotune::Ratios`]
//! becomes observable end-to-end.

pub mod bicgstab;
pub mod cg;
pub mod gmres;
pub mod jacobi;
pub mod pcg;

pub use bicgstab::bicgstab;
pub use cg::cg;
pub use gmres::gmres;
pub use jacobi::jacobi;
pub use pcg::{pcg, pcg_with};

use crate::formats::{Csr, SparseMatrix};
use crate::Result;
use crate::Value;

/// A `y = A·x` operator the solvers iterate with.
pub trait SpmvOp {
    /// Rows of the operator (must be square for these solvers).
    fn n(&self) -> usize;
    /// `y = A·x`.
    fn apply(&mut self, x: &[Value], y: &mut [Value]) -> Result<()>;
    /// Batched `ys[j] = A·xs[j]` — multi-RHS workloads (block methods,
    /// multiple simultaneous systems) funnel through here so operators
    /// with a blocked SpMM kernel ([`crate::spmv::SpmvPlan`]) stream the
    /// matrix once per tile. The default loops [`SpmvOp::apply`].
    fn apply_many(&mut self, xs: &[Vec<Value>], ys: &mut [Vec<Value>]) -> Result<()> {
        anyhow::ensure!(
            xs.len() == ys.len(),
            "batch mismatch: {} inputs vs {} outputs",
            xs.len(),
            ys.len()
        );
        for (x, y) in xs.iter().zip(ys.iter_mut()) {
            self.apply(x, y)?;
        }
        Ok(())
    }
    /// Diagonal of A (needed by Jacobi; default extracts lazily = error).
    fn diagonal(&self) -> Result<Vec<Value>> {
        anyhow::bail!("diagonal not available for this operator")
    }
}

impl SpmvOp for Csr {
    fn n(&self) -> usize {
        self.n_rows()
    }

    fn apply(&mut self, x: &[Value], y: &mut [Value]) -> Result<()> {
        self.spmv(x, y);
        Ok(())
    }

    fn diagonal(&self) -> Result<Vec<Value>> {
        let n = self.n_rows();
        let mut d = vec![0.0; n];
        for i in 0..n {
            for (c, v) in self.row(i) {
                if c as usize == i {
                    d[i] = v;
                }
            }
        }
        Ok(d)
    }
}

impl SpmvOp for crate::spmv::SpmvPlan {
    fn n(&self) -> usize {
        self.n_rows()
    }

    fn apply(&mut self, x: &[Value], y: &mut [Value]) -> Result<()> {
        self.execute(x, y)
    }

    fn apply_many(&mut self, xs: &[Vec<Value>], ys: &mut [Vec<Value>]) -> Result<()> {
        self.execute_many(xs, ys)
    }
}

impl SpmvOp for crate::autotune::atlib::Durmv {
    fn n(&self) -> usize {
        self.csr().n_rows()
    }

    fn apply(&mut self, x: &[Value], y: &mut [Value]) -> Result<()> {
        self.durmv(crate::autotune::atlib::switches::AUTO, x, y)
    }

    fn apply_many(&mut self, xs: &[Vec<Value>], ys: &mut [Vec<Value>]) -> Result<()> {
        self.durmv_many(crate::autotune::atlib::switches::AUTO, xs, ys)
    }

    fn diagonal(&self) -> Result<Vec<Value>> {
        self.csr().diagonal()
    }
}

/// Convergence report shared by the solvers.
#[derive(Clone, Debug, Default)]
pub struct SolveStats {
    /// Iterations executed.
    pub iterations: usize,
    /// Final residual 2-norm.
    pub residual: f64,
    /// Whether the tolerance was met.
    pub converged: bool,
    /// SpMV applications performed (the amortisation denominator).
    pub spmv_calls: usize,
    /// Preconditioner applications performed (0 for unpreconditioned
    /// solvers) — with `spmv_calls`, the full amortisation denominator.
    pub precond_calls: usize,
    /// One-time preconditioner setup cost in wall seconds, whether paid
    /// during this solve or amortised from a coordinator cache (0 for
    /// unpreconditioned solvers).
    pub precond_setup_seconds: f64,
}

/// Solver stopping controls.
#[derive(Clone, Copy, Debug)]
pub struct SolverOptions {
    /// Relative residual tolerance ‖r‖/‖b‖.
    pub tol: f64,
    /// Iteration cap.
    pub max_iters: usize,
}

impl Default for SolverOptions {
    fn default() -> Self {
        Self { tol: 1e-8, max_iters: 1000 }
    }
}

// ---- small dense-vector helpers shared by the solvers ----

pub(crate) fn dot(a: &[Value], b: &[Value]) -> Value {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

pub(crate) fn norm2(a: &[Value]) -> Value {
    dot(a, a).sqrt()
}

/// `y += alpha * x`
pub(crate) fn axpy(alpha: Value, x: &[Value], y: &mut [Value]) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `y = x + beta * y`
pub(crate) fn xpby(x: &[Value], beta: Value, y: &mut [Value]) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = xi + beta * *yi;
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::matrixgen::{make_spd, random_csr};
    use crate::rng::Rng;

    /// A random SPD system (A, b, x_true) of order n.
    pub fn spd_system(seed: u64, n: usize) -> (Csr, Vec<Value>, Vec<Value>) {
        let mut rng = Rng::new(seed);
        let a = make_spd(&random_csr(&mut rng, n, n, 0.08));
        let x_true: Vec<Value> = (0..n).map(|i| ((i + 1) as f64 * 0.173).sin()).collect();
        let mut b = vec![0.0; n];
        a.spmv(&x_true, &mut b);
        (a, b, x_true)
    }

    pub fn assert_solution(x: &[Value], x_true: &[Value], tol: f64) {
        let err: f64 = x
            .iter()
            .zip(x_true)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        let norm = norm2(x_true).max(1e-30);
        assert!(err / norm < tol, "relative error {} > {tol}", err / norm);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn helpers_behave() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(norm2(&[3.0, 4.0]), 5.0);
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[1.0, 2.0], &mut y);
        assert_eq!(y, vec![3.0, 5.0]);
        let mut y = vec![1.0, 2.0];
        xpby(&[10.0, 10.0], 0.5, &mut y);
        assert_eq!(y, vec![10.5, 11.0]);
    }

    #[test]
    fn csr_diagonal_extraction() {
        let a = Csr::from_triplets(3, 3, &[(0, 0, 2.0), (1, 2, 5.0), (2, 2, 7.0)]).unwrap();
        assert_eq!(a.diagonal().unwrap(), vec![2.0, 0.0, 7.0]);
    }

    #[test]
    fn apply_many_default_matches_looped_apply() {
        let mut a =
            Csr::from_triplets(3, 3, &[(0, 0, 2.0), (1, 1, 3.0), (2, 0, 1.0), (2, 2, 4.0)])
                .unwrap();
        let xs = vec![vec![1.0, 2.0, 3.0], vec![-1.0, 0.5, 0.0]];
        let mut want = vec![vec![0.0; 3]; 2];
        for (x, y) in xs.iter().zip(want.iter_mut()) {
            a.apply(x, y).unwrap();
        }
        let mut got = vec![vec![0.0; 3]; 2];
        a.apply_many(&xs, &mut got).unwrap();
        assert_eq!(got, want);
        let mut short = vec![vec![0.0; 3]; 1];
        assert!(a.apply_many(&xs, &mut short).is_err());
    }
}
