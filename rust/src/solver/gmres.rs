//! Restarted GMRES(m) for general systems — completes the solver substrate
//! (OpenATLib's target solvers are Krylov methods of exactly this family).

use super::{norm2, SolveStats, SolverOptions, SpmvOp};
use crate::{Result, Value};

/// Solve `A·x = b` with GMRES restarted every `restart` iterations.
pub fn gmres<Op: SpmvOp + ?Sized>(
    a: &mut Op,
    b: &[Value],
    x: &mut [Value],
    restart: usize,
    opts: &SolverOptions,
) -> Result<SolveStats> {
    let n = a.n();
    anyhow::ensure!(b.len() == n && x.len() == n, "dimension mismatch");
    anyhow::ensure!(restart >= 1, "restart must be >= 1");
    let m = restart.min(n);
    let bnorm = norm2(b).max(f64::MIN_POSITIVE);
    let mut spmv_calls = 0usize;
    let mut total_iters = 0usize;

    let mut r = vec![0.0; n];
    loop {
        // r = b - A x
        a.apply(x, &mut r)?;
        spmv_calls += 1;
        for i in 0..n {
            r[i] = b[i] - r[i];
        }
        let beta = norm2(&r);
        if beta / bnorm <= opts.tol {
            return Ok(SolveStats {
                iterations: total_iters,
                residual: beta,
                converged: true,
                spmv_calls,
                ..Default::default()
            });
        }
        if total_iters >= opts.max_iters {
            return Ok(SolveStats {
                iterations: total_iters,
                residual: beta,
                converged: false,
                spmv_calls,
                ..Default::default()
            });
        }

        // Arnoldi with modified Gram-Schmidt; Givens-rotated least squares.
        let mut v: Vec<Vec<Value>> = Vec::with_capacity(m + 1);
        v.push(r.iter().map(|&ri| ri / beta).collect());
        let mut h = vec![vec![0.0; m]; m + 1]; // (m+1) x m Hessenberg
        let mut cs = vec![0.0; m];
        let mut sn = vec![0.0; m];
        let mut g = vec![0.0; m + 1];
        g[0] = beta;
        let mut k_used = 0usize;

        for k in 0..m {
            if total_iters >= opts.max_iters {
                break;
            }
            let mut w = vec![0.0; n];
            a.apply(&v[k], &mut w)?;
            spmv_calls += 1;
            total_iters += 1;
            for j in 0..=k {
                let hjk = super::dot(&w, &v[j]);
                h[j][k] = hjk;
                super::axpy(-hjk, &v[j], &mut w);
            }
            let wn = norm2(&w);
            h[k + 1][k] = wn;
            // Apply previous rotations to the new column.
            for j in 0..k {
                let t = cs[j] * h[j][k] + sn[j] * h[j + 1][k];
                h[j + 1][k] = -sn[j] * h[j][k] + cs[j] * h[j + 1][k];
                h[j][k] = t;
            }
            // New rotation annihilating h[k+1][k].
            let denom = (h[k][k] * h[k][k] + h[k + 1][k] * h[k + 1][k]).sqrt();
            if denom < 1e-300 {
                k_used = k + 1;
                break;
            }
            cs[k] = h[k][k] / denom;
            sn[k] = h[k + 1][k] / denom;
            h[k][k] = denom;
            h[k + 1][k] = 0.0;
            g[k + 1] = -sn[k] * g[k];
            g[k] *= cs[k];
            k_used = k + 1;
            let res = g[k + 1].abs();
            if wn < 1e-300 || res / bnorm <= opts.tol {
                break;
            }
            v.push(w.iter().map(|&wi| wi / wn).collect());
        }

        // Back-substitute y from the triangular system, update x.
        let k = k_used;
        if k == 0 {
            return Ok(SolveStats {
                iterations: total_iters,
                residual: beta,
                converged: beta / bnorm <= opts.tol,
                spmv_calls,
                ..Default::default()
            });
        }
        let mut y = vec![0.0; k];
        for i in (0..k).rev() {
            let mut s = g[i];
            for j in i + 1..k {
                s -= h[i][j] * y[j];
            }
            y[i] = s / h[i][i];
        }
        for (j, yj) in y.iter().enumerate() {
            super::axpy(*yj, &v[j], x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{assert_solution, spd_system};
    use super::*;
    use crate::formats::{Csr, SparseMatrix};
    use crate::matrixgen::random_csr;
    use crate::rng::Rng;

    fn unsym_system(seed: u64, n: usize) -> (Csr, Vec<Value>, Vec<Value>) {
        let mut rng = Rng::new(seed);
        let a = random_csr(&mut rng, n, n, 0.1);
        let mut t = a.to_triplets();
        for i in 0..n {
            let row_sum: f64 = a.row(i).map(|(_, v)| v.abs()).sum();
            t.push((i, i, row_sum + 1.0));
        }
        let a = Csr::from_triplets(n, n, &t).unwrap();
        let x_true: Vec<Value> = (0..n).map(|i| ((i + 2) as f64 * 0.149).sin()).collect();
        let mut b = vec![0.0; n];
        a.spmv(&x_true, &mut b);
        (a, b, x_true)
    }

    #[test]
    fn gmres_solves_unsymmetric_system() {
        let (mut a, b, x_true) = unsym_system(31, 120);
        let mut x = vec![0.0; 120];
        let stats = gmres(&mut a, &b, &mut x, 30, &SolverOptions::default()).unwrap();
        assert!(stats.converged, "residual {}", stats.residual);
        assert_solution(&x, &x_true, 1e-6);
    }

    #[test]
    fn gmres_with_tiny_restart_still_converges() {
        let (mut a, b, x_true) = spd_system(32, 60);
        let mut x = vec![0.0; 60];
        let opts = SolverOptions { tol: 1e-8, max_iters: 5000 };
        let stats = gmres(&mut a, &b, &mut x, 5, &opts).unwrap();
        assert!(stats.converged, "residual {}", stats.residual);
        assert_solution(&x, &x_true, 1e-5);
    }

    #[test]
    fn gmres_zero_rhs() {
        let (mut a, _, _) = unsym_system(33, 20);
        let b = vec![0.0; 20];
        let mut x = vec![0.0; 20];
        let stats = gmres(&mut a, &b, &mut x, 10, &SolverOptions::default()).unwrap();
        assert!(stats.converged);
        assert_eq!(stats.iterations, 0);
    }

    #[test]
    fn gmres_respects_cap() {
        let (mut a, b, _) = unsym_system(34, 80);
        let mut x = vec![0.0; 80];
        let opts = SolverOptions { tol: 1e-300, max_iters: 7 };
        let stats = gmres(&mut a, &b, &mut x, 4, &opts).unwrap();
        assert!(!stats.converged);
        assert!(stats.iterations >= 7, "{stats:?}");
    }

    #[test]
    fn gmres_rejects_zero_restart() {
        let (mut a, b, _) = unsym_system(35, 10);
        let mut x = vec![0.0; 10];
        assert!(gmres(&mut a, &b, &mut x, 0, &SolverOptions::default()).is_err());
    }
}
