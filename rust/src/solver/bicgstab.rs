//! BiCGStab for general (unsymmetric) systems — most Table-1 matrices are
//! unsymmetric, so this is the solver their applications would actually run.

use super::{axpy, dot, norm2, SolveStats, SolverOptions, SpmvOp};
use crate::{Result, Value};

/// Solve `A·x = b` with BiCGStab (van der Vorst). `x` carries the initial
/// guess in and the solution out.
pub fn bicgstab<Op: SpmvOp + ?Sized>(
    a: &mut Op,
    b: &[Value],
    x: &mut [Value],
    opts: &SolverOptions,
) -> Result<SolveStats> {
    let n = a.n();
    anyhow::ensure!(b.len() == n && x.len() == n, "dimension mismatch");
    let bnorm = norm2(b).max(f64::MIN_POSITIVE);
    let mut spmv_calls = 0usize;

    let mut r = vec![0.0; n];
    a.apply(x, &mut r)?;
    spmv_calls += 1;
    for i in 0..n {
        r[i] = b[i] - r[i];
    }
    let r0 = r.clone(); // shadow residual
    let mut rho_prev = 1.0;
    let mut alpha = 1.0;
    let mut omega = 1.0;
    let mut v = vec![0.0; n];
    let mut p = vec![0.0; n];
    let mut s = vec![0.0; n];
    let mut t = vec![0.0; n];

    for k in 0..opts.max_iters {
        let res = norm2(&r);
        if res / bnorm <= opts.tol {
            return Ok(SolveStats {
                iterations: k,
                residual: res,
                converged: true,
                spmv_calls,
                ..Default::default()
            });
        }
        let rho = dot(&r0, &r);
        anyhow::ensure!(rho.abs() > 1e-300, "BiCGStab breakdown: rho = {rho}");
        if k == 0 {
            p.copy_from_slice(&r);
        } else {
            let beta = (rho / rho_prev) * (alpha / omega);
            for i in 0..n {
                p[i] = r[i] + beta * (p[i] - omega * v[i]);
            }
        }
        a.apply(&p, &mut v)?;
        spmv_calls += 1;
        let r0v = dot(&r0, &v);
        anyhow::ensure!(r0v.abs() > 1e-300, "BiCGStab breakdown: r0·v = {r0v}");
        alpha = rho / r0v;
        for i in 0..n {
            s[i] = r[i] - alpha * v[i];
        }
        // Early half-step convergence.
        let snorm = norm2(&s);
        if snorm / bnorm <= opts.tol {
            axpy(alpha, &p, x);
            return Ok(SolveStats {
                iterations: k + 1,
                residual: snorm,
                converged: true,
                spmv_calls,
                ..Default::default()
            });
        }
        a.apply(&s, &mut t)?;
        spmv_calls += 1;
        let tt = dot(&t, &t);
        anyhow::ensure!(tt > 1e-300, "BiCGStab breakdown: t·t = {tt}");
        omega = dot(&t, &s) / tt;
        anyhow::ensure!(omega.abs() > 1e-300, "BiCGStab breakdown: omega = {omega}");
        for i in 0..n {
            x[i] += alpha * p[i] + omega * s[i];
            r[i] = s[i] - omega * t[i];
        }
        rho_prev = rho;
    }
    let res = norm2(&r);
    Ok(SolveStats {
        iterations: opts.max_iters,
        residual: res,
        converged: res / bnorm <= opts.tol,
        spmv_calls,
        ..Default::default()
    })
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{assert_solution, spd_system};
    use super::*;
    use crate::formats::{Csr, SparseMatrix};
    use crate::matrixgen::random_csr;
    use crate::rng::Rng;

    /// Unsymmetric diagonally dominant system.
    fn unsym_system(seed: u64, n: usize) -> (Csr, Vec<Value>, Vec<Value>) {
        let mut rng = Rng::new(seed);
        let a = random_csr(&mut rng, n, n, 0.08);
        let mut t = a.to_triplets();
        // Dominant diagonal (keeps the spectrum in the right half plane).
        for i in 0..n {
            let row_sum: f64 = a.row(i).map(|(_, v)| v.abs()).sum();
            t.push((i, i, row_sum + 1.0));
        }
        let a = Csr::from_triplets(n, n, &t).unwrap();
        let x_true: Vec<Value> = (0..n).map(|i| ((i * 3 + 1) as f64 * 0.211).cos()).collect();
        let mut b = vec![0.0; n];
        a.spmv(&x_true, &mut b);
        (a, b, x_true)
    }

    #[test]
    fn bicgstab_solves_unsymmetric_system() {
        let (mut a, b, x_true) = unsym_system(21, 150);
        let mut x = vec![0.0; 150];
        let stats = bicgstab(&mut a, &b, &mut x, &SolverOptions::default()).unwrap();
        assert!(stats.converged, "residual {}", stats.residual);
        assert_solution(&x, &x_true, 1e-6);
    }

    #[test]
    fn bicgstab_also_handles_spd() {
        let (mut a, b, x_true) = spd_system(22, 90);
        let mut x = vec![0.0; 90];
        let stats = bicgstab(&mut a, &b, &mut x, &SolverOptions::default()).unwrap();
        assert!(stats.converged);
        assert_solution(&x, &x_true, 1e-6);
    }

    #[test]
    fn bicgstab_counts_two_spmv_per_iteration() {
        let (mut a, b, _) = unsym_system(23, 60);
        let mut x = vec![0.0; 60];
        let stats = bicgstab(&mut a, &b, &mut x, &SolverOptions::default()).unwrap();
        // 1 initial + ~2 per full iteration.
        assert!(stats.spmv_calls >= stats.iterations, "{stats:?}");
        assert!(stats.spmv_calls <= 2 * stats.iterations + 2, "{stats:?}");
    }

    #[test]
    fn bicgstab_zero_rhs() {
        let (mut a, _, _) = unsym_system(24, 30);
        let b = vec![0.0; 30];
        let mut x = vec![0.0; 30];
        let stats = bicgstab(&mut a, &b, &mut x, &SolverOptions::default()).unwrap();
        assert!(stats.converged);
        assert_eq!(stats.iterations, 0);
    }
}
