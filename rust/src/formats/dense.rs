//! Dense matrix — correctness oracle for the sparse kernels and the
//! reference the property tests compare everything against.

use super::{FormatKind, SparseMatrix};
use crate::{Result, Value};

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Dense {
    n_rows: usize,
    n_cols: usize,
    /// Row-major storage: entry (i, j) at `data[i*n_cols + j]`.
    pub data: Vec<Value>,
}

impl Dense {
    /// All-zeros matrix.
    pub fn zeros(n_rows: usize, n_cols: usize) -> Self {
        Self { n_rows, n_cols, data: vec![0.0; n_rows * n_cols] }
    }

    /// Build from triplets (duplicates summed).
    pub fn from_triplets(
        n_rows: usize,
        n_cols: usize,
        triplets: &[(usize, usize, Value)],
    ) -> Result<Self> {
        super::check_triplets(n_rows, n_cols, triplets)?;
        let mut m = Self::zeros(n_rows, n_cols);
        for &(r, c, v) in triplets {
            m.data[r * n_cols + c] += v;
        }
        Ok(m)
    }

    /// Entry accessor.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> Value {
        self.data[i * self.n_cols + j]
    }

    /// Mutable entry accessor.
    #[inline]
    pub fn get_mut(&mut self, i: usize, j: usize) -> &mut Value {
        &mut self.data[i * self.n_cols + j]
    }

    /// Count of exact non-zeros.
    pub fn count_nonzeros(&self) -> usize {
        self.data.iter().filter(|&&v| v != 0.0).count()
    }
}

impl SparseMatrix for Dense {
    fn n_rows(&self) -> usize {
        self.n_rows
    }

    fn n_cols(&self) -> usize {
        self.n_cols
    }

    fn nnz(&self) -> usize {
        self.count_nonzeros()
    }

    fn memory_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<Value>()
    }

    fn spmv(&self, x: &[Value], y: &mut [Value]) {
        assert_eq!(x.len(), self.n_cols, "x length");
        assert_eq!(y.len(), self.n_rows, "y length");
        for i in 0..self.n_rows {
            let row = &self.data[i * self.n_cols..(i + 1) * self.n_cols];
            y[i] = row.iter().zip(x).map(|(a, b)| a * b).sum();
        }
    }

    fn kind(&self) -> FormatKind {
        // Dense is not an AT target; report as the baseline.
        FormatKind::Csr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_spmv() {
        let d = Dense::from_triplets(2, 3, &[(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0)]).unwrap();
        let mut y = vec![0.0; 2];
        d.spmv(&[1.0, 2.0, 3.0], &mut y);
        assert_eq!(y, vec![7.0, 6.0]);
        assert_eq!(d.nnz(), 3);
        assert_eq!(d.get(0, 2), 2.0);
    }

    #[test]
    fn duplicates_summed() {
        let d = Dense::from_triplets(1, 1, &[(0, 0, 1.0), (0, 0, 2.0)]).unwrap();
        assert_eq!(d.get(0, 0), 3.0);
        assert_eq!(d.nnz(), 1);
    }
}
