//! ELLPACK/ITPACK storage — the paper's ELL format.
//!
//! `VAL(1:n, 1:nz)` and `ICOL(1:n, 1:nz)` are stored **band-major**
//! (Fortran column-major): band `k` occupies the contiguous slice
//! `val[k*n .. (k+1)*n]`, exactly the `J_PTR = N*(K-1) + I` addressing of
//! the paper's Figs. 3–4. Rows shorter than the bandwidth `nz` are padded
//! with explicit zeros whose column index points at column 0 (a harmless
//! `0.0 * x[0]` contribution).
//!
//! Band-major layout is what gives ELL its vector-machine advantage: the
//! inner `I = 1..N` loop of Fig. 3 walks `val` with unit stride over the
//! whole matrix dimension `n`, so the SX-9's vector pipes run at full
//! length instead of the per-row short vectors CRS yields.

use super::{FormatKind, SparseMatrix};
use crate::{Index, Result, Value};

/// ELL sparse matrix with band-major padded storage.
#[derive(Clone, Debug, PartialEq)]
pub struct Ell {
    n_rows: usize,
    n_cols: usize,
    /// Bandwidth `nz` — the maximum row population; every row is padded to it.
    pub bandwidth: usize,
    /// Stored non-zeros excluding padding.
    logical_nnz: usize,
    /// `VAL`, band-major: entry (row `i`, band `k`) at `values[k*n_rows + i]`.
    pub values: Vec<Value>,
    /// `ICOL`, band-major, same addressing; padding points at column 0.
    pub col_idx: Vec<Index>,
}

impl Ell {
    /// Build from raw band-major arrays.
    pub fn new(
        n_rows: usize,
        n_cols: usize,
        bandwidth: usize,
        values: Vec<Value>,
        col_idx: Vec<Index>,
        logical_nnz: usize,
    ) -> Result<Self> {
        anyhow::ensure!(
            values.len() == n_rows * bandwidth,
            "values length {} != n*nz = {}",
            values.len(),
            n_rows * bandwidth
        );
        anyhow::ensure!(
            col_idx.len() == values.len(),
            "col_idx/values length mismatch"
        );
        for &c in &col_idx {
            anyhow::ensure!(
                (c as usize) < n_cols.max(1),
                "column {c} out of bounds {n_cols}"
            );
        }
        anyhow::ensure!(
            logical_nnz <= values.len(),
            "logical nnz {} exceeds storage {}",
            logical_nnz,
            values.len()
        );
        Ok(Self { n_rows, n_cols, bandwidth, logical_nnz, values, col_idx })
    }

    /// Flat band-major offset of (row `i`, band `k`) — the paper's
    /// `J_PTR = N*(K-1) + I` in zero-based form.
    #[inline]
    pub fn offset(&self, i: usize, k: usize) -> usize {
        k * self.n_rows + i
    }

    /// Padding ratio: stored slots / logical non-zeros (1.0 = perfect band).
    /// This is the memory- and compute-waste factor the `D_mat` statistic
    /// predicts (paper §4.5). Degenerate matrices (`n_rows == 0` or zero
    /// stored entries — the second implies the first's 0/0 case) are
    /// defined as exactly 1.0 so no NaN ratio can propagate into the
    /// D_mat–R model or the learned-table buckets.
    pub fn fill_ratio(&self) -> f64 {
        if self.n_rows == 0 || self.logical_nnz == 0 {
            1.0
        } else {
            (self.n_rows * self.bandwidth) as f64 / self.logical_nnz as f64
        }
    }

    /// Number of padded (explicit zero) slots.
    pub fn padding(&self) -> usize {
        self.n_rows * self.bandwidth - self.logical_nnz
    }
}

impl SparseMatrix for Ell {
    fn n_rows(&self) -> usize {
        self.n_rows
    }

    fn n_cols(&self) -> usize {
        self.n_cols
    }

    fn nnz(&self) -> usize {
        self.logical_nnz
    }

    fn memory_bytes(&self) -> usize {
        self.values.len() * std::mem::size_of::<Value>()
            + self.col_idx.len() * std::mem::size_of::<Index>()
    }

    /// Sequential band-loop SpMV (the sequential core of Fig. 3):
    /// for each band, stream `val[k*n..]` with unit stride accumulating
    /// into `y`.
    fn spmv(&self, x: &[Value], y: &mut [Value]) {
        assert_eq!(x.len(), self.n_cols, "x length");
        assert_eq!(y.len(), self.n_rows, "y length");
        y.fill(0.0);
        for k in 0..self.bandwidth {
            let base = k * self.n_rows;
            let vals = &self.values[base..base + self.n_rows];
            let cols = &self.col_idx[base..base + self.n_rows];
            // Zipped sweep: one bounds check per band instead of per slot
            // (perf pass, EXPERIMENTS.md §Perf).
            for ((yi, &v), &c) in y.iter_mut().zip(vals).zip(cols) {
                *yi += v * x[c as usize];
            }
        }
    }

    fn kind(&self) -> FormatKind {
        FormatKind::Ell
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Csr;
    use crate::transform::crs_to_ell;

    fn sample_csr() -> Csr {
        Csr::from_triplets(
            3,
            3,
            &[(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0), (2, 0, 4.0), (2, 2, 5.0)],
        )
        .unwrap()
    }

    #[test]
    fn band_major_addressing() {
        let e = crs_to_ell(&sample_csr()).unwrap();
        assert_eq!(e.bandwidth, 2);
        // Band 0: first entry of each row -> values [1,3,4].
        assert_eq!(&e.values[0..3], &[1.0, 3.0, 4.0]);
        // Band 1: second entry or padding -> [2, 0(pad), 5].
        assert_eq!(&e.values[3..6], &[2.0, 0.0, 5.0]);
        assert_eq!(e.offset(1, 1), 4);
    }

    #[test]
    fn spmv_matches_csr() {
        let a = sample_csr();
        let e = crs_to_ell(&a).unwrap();
        let x = [1.0, 2.0, 3.0];
        let mut y1 = vec![0.0; 3];
        let mut y2 = vec![0.0; 3];
        a.spmv(&x, &mut y1);
        e.spmv(&x, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn fill_ratio_and_padding() {
        let e = crs_to_ell(&sample_csr()).unwrap();
        assert_eq!(e.nnz(), 5);
        assert_eq!(e.padding(), 1);
        assert!((e.fill_ratio() - 6.0 / 5.0).abs() < 1e-12);
    }

    #[test]
    fn perfect_band_has_unit_fill() {
        // Tridiagonal interior rows all have 3 entries; use a circulant so
        // every row has exactly 2.
        let t: Vec<(usize, usize, Value)> =
            (0..4).flat_map(|i| vec![(i, i, 2.0), (i, (i + 1) % 4, 1.0)]).collect();
        let a = Csr::from_triplets(4, 4, &t).unwrap();
        let e = crs_to_ell(&a).unwrap();
        assert_eq!(e.fill_ratio(), 1.0);
        assert_eq!(e.padding(), 0);
    }

    #[test]
    fn invalid_inputs_rejected() {
        assert!(Ell::new(2, 2, 2, vec![0.0; 3], vec![0; 3], 3).is_err()); // wrong len
        assert!(Ell::new(2, 2, 1, vec![0.0; 2], vec![0, 9], 2).is_err()); // col oob
        assert!(Ell::new(2, 2, 1, vec![0.0; 2], vec![0, 0], 5).is_err()); // nnz too big
    }

    #[test]
    fn empty_matrix() {
        let e = Ell::new(0, 0, 0, vec![], vec![], 0).unwrap();
        let mut y = vec![];
        e.spmv(&[], &mut y);
        assert_eq!(e.fill_ratio(), 1.0);
    }
}
