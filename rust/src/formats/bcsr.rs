//! Block CSR (BCSR) — register-blocked CSR with fixed `r × c` dense blocks.
//!
//! The paper names "transformation to other formats, such as BCSR, which
//! enables cache blocking" as future work (§5); it is implemented here as a
//! first-class extension so the ablation benches can compare it against ELL
//! on the same auto-tuning machinery.

use super::{FormatKind, SparseMatrix};
use crate::formats::Csr;
use crate::{Index, Result, Value};

/// BCSR sparse matrix: a CSR structure over dense `r × c` blocks. Blocks are
/// stored row-major within `values` (`block_nnz * r * c` scalars); logical
/// rows/cols that don't divide the block size are zero-padded.
#[derive(Clone, Debug, PartialEq)]
pub struct Bcsr {
    n_rows: usize,
    n_cols: usize,
    /// Block height `r`.
    pub br: usize,
    /// Block width `c`.
    pub bc: usize,
    /// Block-row pointers, length `ceil(n_rows/br) + 1`.
    pub block_row_ptr: Vec<usize>,
    /// Block-column index per stored block.
    pub block_col_idx: Vec<Index>,
    /// Block payloads, row-major `br*bc` scalars per block.
    pub values: Vec<Value>,
    /// Logical (unpadded) nnz of the source matrix.
    logical_nnz: usize,
}

impl Bcsr {
    /// Blocked row count.
    pub fn n_block_rows(&self) -> usize {
        self.block_row_ptr.len() - 1
    }

    /// Number of stored blocks.
    pub fn n_blocks(&self) -> usize {
        self.block_col_idx.len()
    }

    /// Fill ratio: stored scalars / logical nnz (≥ 1.0; 1.0 = perfect blocks).
    pub fn fill_ratio(&self) -> f64 {
        if self.logical_nnz == 0 {
            1.0
        } else {
            (self.n_blocks() * self.br * self.bc) as f64 / self.logical_nnz as f64
        }
    }

    /// Build from CSR with block shape `br × bc`.
    pub fn from_csr(a: &Csr, br: usize, bc: usize) -> Result<Self> {
        anyhow::ensure!(br > 0 && bc > 0, "block dims must be positive");
        use crate::formats::SparseMatrix as _;
        let n_rows = a.n_rows();
        let n_cols = a.n_cols();
        let nbr = n_rows.div_ceil(br);
        let mut block_row_ptr = vec![0usize; nbr + 1];
        let mut block_col_idx: Vec<Index> = Vec::new();
        let mut values: Vec<Value> = Vec::new();

        // Per block-row: discover populated block columns, then fill.
        let mut touched: Vec<Index> = Vec::new();
        for bi in 0..nbr {
            touched.clear();
            let r_lo = bi * br;
            let r_hi = (r_lo + br).min(n_rows);
            for i in r_lo..r_hi {
                for (c, _) in a.row(i) {
                    let bj = c / bc as Index;
                    if let Err(pos) = touched.binary_search(&bj) {
                        touched.insert(pos, bj);
                    }
                }
            }
            let base_block = block_col_idx.len();
            block_col_idx.extend_from_slice(&touched);
            values.resize(values.len() + touched.len() * br * bc, 0.0);
            for i in r_lo..r_hi {
                for (c, v) in a.row(i) {
                    let bj = c / bc as Index;
                    let slot = base_block + touched.binary_search(&bj).unwrap();
                    let local = (i - r_lo) * bc + (c as usize - bj as usize * bc);
                    values[slot * br * bc + local] += v;
                }
            }
            block_row_ptr[bi + 1] = block_col_idx.len();
        }
        Ok(Self {
            n_rows,
            n_cols,
            br,
            bc,
            block_row_ptr,
            block_col_idx,
            values,
            logical_nnz: a.nnz(),
        })
    }
}

impl SparseMatrix for Bcsr {
    fn n_rows(&self) -> usize {
        self.n_rows
    }

    fn n_cols(&self) -> usize {
        self.n_cols
    }

    fn nnz(&self) -> usize {
        self.logical_nnz
    }

    fn memory_bytes(&self) -> usize {
        self.values.len() * std::mem::size_of::<Value>()
            + self.block_col_idx.len() * std::mem::size_of::<Index>()
            + self.block_row_ptr.len() * std::mem::size_of::<usize>()
    }

    /// Register-blocked SpMV: each block contributes a small dense
    /// `br × bc` mat-vec kept in registers.
    fn spmv(&self, x: &[Value], y: &mut [Value]) {
        assert_eq!(x.len(), self.n_cols, "x length");
        assert_eq!(y.len(), self.n_rows, "y length");
        y.fill(0.0);
        let (br, bc) = (self.br, self.bc);
        for bi in 0..self.n_block_rows() {
            let r_lo = bi * br;
            let r_cap = (self.n_rows - r_lo).min(br);
            for s in self.block_row_ptr[bi]..self.block_row_ptr[bi + 1] {
                let bj = self.block_col_idx[s] as usize;
                let c_lo = bj * bc;
                let c_cap = (self.n_cols - c_lo).min(bc);
                let blk = &self.values[s * br * bc..(s + 1) * br * bc];
                for di in 0..r_cap {
                    let mut acc = 0.0;
                    let row = &blk[di * bc..di * bc + c_cap];
                    for (dj, &v) in row.iter().enumerate() {
                        acc += v * x[c_lo + dj];
                    }
                    y[r_lo + di] += acc;
                }
            }
        }
    }

    fn kind(&self) -> FormatKind {
        FormatKind::Bcsr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        Csr::from_triplets(
            5,
            5,
            &[
                (0, 0, 1.0),
                (0, 1, 2.0),
                (1, 0, 3.0),
                (2, 3, 4.0),
                (3, 2, 5.0),
                (4, 4, 6.0),
                (4, 0, 7.0),
            ],
        )
        .unwrap()
    }

    #[test]
    fn spmv_matches_csr_for_various_blocks() {
        let a = sample();
        let x = [1.0, -1.0, 2.0, 0.5, 3.0];
        let mut want = vec![0.0; 5];
        a.spmv(&x, &mut want);
        for &(br, bc) in &[(1usize, 1usize), (2, 2), (3, 2), (2, 3), (4, 4), (5, 5), (8, 8)] {
            let b = Bcsr::from_csr(&a, br, bc).unwrap();
            let mut got = vec![0.0; 5];
            b.spmv(&x, &mut got);
            assert_eq!(got, want, "block {br}x{bc}");
            assert_eq!(b.nnz(), a.nnz());
            assert!(b.fill_ratio() >= 1.0);
        }
    }

    #[test]
    fn one_by_one_blocks_have_csr_fill() {
        let a = sample();
        let b = Bcsr::from_csr(&a, 1, 1).unwrap();
        assert_eq!(b.fill_ratio(), 1.0);
        assert_eq!(b.n_blocks(), a.nnz());
    }

    #[test]
    fn dense_block_matrix_perfect_fill() {
        // 4x4 matrix of one dense 2x2 block at top-left and one at bottom-right.
        let t = [
            (0, 0, 1.0), (0, 1, 1.0), (1, 0, 1.0), (1, 1, 1.0),
            (2, 2, 1.0), (2, 3, 1.0), (3, 2, 1.0), (3, 3, 1.0),
        ];
        let a = Csr::from_triplets(4, 4, &t).unwrap();
        let b = Bcsr::from_csr(&a, 2, 2).unwrap();
        assert_eq!(b.n_blocks(), 2);
        assert_eq!(b.fill_ratio(), 1.0);
    }

    #[test]
    fn rejects_zero_blocks() {
        assert!(Bcsr::from_csr(&sample(), 0, 2).is_err());
        assert!(Bcsr::from_csr(&sample(), 2, 0).is_err());
    }
}
