//! SELL-C-σ storage — sliced ELL with σ-window row sorting (Kreutzer et
//! al., the SIMD-friendly successor to ELLPACK; PAPERS.md).
//!
//! Rows are reordered by descending length inside windows of `σ`
//! consecutive rows, then grouped into chunks of `C` rows. Each chunk is
//! padded only to *its own* widest row and stored band-major within the
//! chunk: band `k` of chunk `q` is the contiguous slice
//! `values[chunk_off[q] + k*rows .. +rows]` (`rows` = chunk height, `C`
//! except possibly the tail chunk). The inner SpMV loop is therefore a
//! unit-stride lane-width-`C` sweep — the explicit vector-lane layout the
//! `machine/vector.rs` cost model prices, realised on the host.
//!
//! Two properties the rest of the crate relies on:
//!
//! * **Bitwise row sums.** Each row's entries are stored in CSR
//!   left-to-right order along the band axis and each output row is
//!   accumulated by exactly one lane, so per-row results are
//!   bitwise-identical to sequential CRS. Padding slots are *never*
//!   accumulated (the kernels stop at [`SellCSigma::row_len`], not the
//!   chunk width), so `-0.0`/`inf`/`NaN` in `x` cannot leak a padded
//!   `0.0 * x[0]` into a sum.
//! * **Row permutation at the output merge.** [`SellCSigma::perm`] maps
//!   sorted slot → original row; kernels write `y[perm[slot]]`, so the
//!   served vector is in original row order and the format qualifies for
//!   `Implementation::split_stable` row-block splitting.

use super::{FormatKind, SparseMatrix};
use crate::{Index, Result, Value};

/// Largest admissible chunk height `C`. Kernels keep one accumulator per
/// lane in a fixed stack array, so `C` is capped (any realistic vector
/// width is far below this; the env knob clamps to it).
pub const MAX_C: usize = 256;

/// SELL-C-σ sparse matrix: chunked, per-chunk padded, σ-sorted.
#[derive(Clone, Debug, PartialEq)]
pub struct SellCSigma {
    n_rows: usize,
    n_cols: usize,
    /// Chunk height `C` — the kernel lane width (1 ≤ C ≤ [`MAX_C`]).
    pub c: usize,
    /// Sort window `σ`: rows are length-sorted only inside windows of
    /// this many consecutive rows (σ = 1 ⇒ no reordering, σ ≥ n ⇒ global
    /// sort).
    pub sigma: usize,
    /// Per-chunk padded width (the chunk's longest row).
    pub chunk_width: Vec<usize>,
    /// Per-chunk start offset into `values`/`col_idx`; chunk `q` spans
    /// `chunk_off[q] .. chunk_off[q] + chunk_width[q] * rows(q)`.
    pub chunk_off: Vec<usize>,
    /// Sorted slot → original row (`perm[q*C + i]` is the matrix row lane
    /// `i` of chunk `q` computes).
    pub perm: Vec<Index>,
    /// Per-sorted-slot logical row length; kernels accumulate exactly
    /// this many bands per lane, never the padding.
    pub row_len: Vec<Index>,
    /// `VAL`, chunk-band-major: (chunk `q`, band `k`, lane `i`) at
    /// `chunk_off[q] + k*rows(q) + i`. Padding slots hold `0.0`.
    pub values: Vec<Value>,
    /// `ICOL`, same addressing; padding slots point at column 0.
    pub col_idx: Vec<Index>,
    /// Stored non-zeros excluding padding.
    logical_nnz: usize,
}

impl SellCSigma {
    /// Build from raw parts, validating every structural invariant (the
    /// transform builders construct these consistently; this constructor
    /// is the single gate).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        n_rows: usize,
        n_cols: usize,
        c: usize,
        sigma: usize,
        chunk_width: Vec<usize>,
        chunk_off: Vec<usize>,
        perm: Vec<Index>,
        row_len: Vec<Index>,
        values: Vec<Value>,
        col_idx: Vec<Index>,
    ) -> Result<Self> {
        anyhow::ensure!((1..=MAX_C).contains(&c), "chunk height C={c} outside 1..={MAX_C}");
        anyhow::ensure!(sigma >= 1, "sort window sigma must be >= 1");
        let n_chunks = n_rows.div_ceil(c);
        anyhow::ensure!(
            chunk_width.len() == n_chunks && chunk_off.len() == n_chunks,
            "chunk arrays must have ceil(n/C) = {n_chunks} entries"
        );
        anyhow::ensure!(
            perm.len() == n_rows && row_len.len() == n_rows,
            "perm/row_len must have one entry per row"
        );
        let mut seen = vec![false; n_rows];
        for &p in &perm {
            let p = p as usize;
            anyhow::ensure!(p < n_rows && !seen[p], "perm is not a permutation of 0..{n_rows}");
            seen[p] = true;
        }
        let mut expect_off = 0usize;
        let mut logical_nnz = 0usize;
        for q in 0..n_chunks {
            anyhow::ensure!(chunk_off[q] == expect_off, "chunk_off[{q}] != running span");
            let rows = c.min(n_rows - q * c);
            for i in 0..rows {
                let len = row_len[q * c + i] as usize;
                anyhow::ensure!(
                    len <= chunk_width[q],
                    "row_len {len} exceeds chunk_width[{q}] = {}",
                    chunk_width[q]
                );
                logical_nnz += len;
            }
            expect_off += chunk_width[q] * rows;
        }
        anyhow::ensure!(
            values.len() == expect_off && col_idx.len() == expect_off,
            "storage length {} != padded span {expect_off}",
            values.len()
        );
        for &col in &col_idx {
            anyhow::ensure!(
                (col as usize) < n_cols.max(1),
                "column {col} out of bounds {n_cols}"
            );
        }
        Ok(Self {
            n_rows,
            n_cols,
            c,
            sigma,
            chunk_width,
            chunk_off,
            perm,
            row_len,
            values,
            col_idx,
            logical_nnz,
        })
    }

    /// Number of chunks (`⌈n/C⌉`).
    #[inline]
    pub fn n_chunks(&self) -> usize {
        self.chunk_width.len()
    }

    /// Height of chunk `q` (`C`, except a shorter tail chunk).
    #[inline]
    pub fn chunk_rows(&self, q: usize) -> usize {
        self.c.min(self.n_rows - q * self.c)
    }

    /// Total padded slots actually stored (Σ width·rows over chunks).
    #[inline]
    pub fn padded_slots(&self) -> usize {
        self.values.len()
    }

    /// Padding ratio: padded slots / logical non-zeros. Defined as 1.0
    /// for degenerate matrices (`n_rows == 0` or zero stored entries) so
    /// no NaN can reach the D_mat–R model or the learned-table buckets.
    pub fn fill_ratio(&self) -> f64 {
        if self.n_rows == 0 || self.logical_nnz == 0 {
            1.0
        } else {
            self.padded_slots() as f64 / self.logical_nnz as f64
        }
    }

    /// Number of padding (explicit zero) slots.
    #[inline]
    pub fn padding(&self) -> usize {
        self.padded_slots() - self.logical_nnz
    }
}

impl SparseMatrix for SellCSigma {
    fn n_rows(&self) -> usize {
        self.n_rows
    }

    fn n_cols(&self) -> usize {
        self.n_cols
    }

    fn nnz(&self) -> usize {
        self.logical_nnz
    }

    fn memory_bytes(&self) -> usize {
        self.values.len() * std::mem::size_of::<Value>()
            + self.col_idx.len() * std::mem::size_of::<Index>()
            + self.perm.len() * std::mem::size_of::<Index>()
            + self.row_len.len() * std::mem::size_of::<Index>()
            + (self.chunk_width.len() + self.chunk_off.len()) * std::mem::size_of::<usize>()
    }

    /// Sequential chunked SpMV: per chunk, lane accumulators sweep full
    /// bands (`k < min_len`, every lane active — the unit-stride vector
    /// loop) then the ragged tail with a per-lane length guard, and the
    /// result merges through the permutation. Per-row accumulation is
    /// left-to-right in CSR order, so the output is bitwise-identical to
    /// [`Csr::spmv`](super::Csr).
    fn spmv(&self, x: &[Value], y: &mut [Value]) {
        assert_eq!(x.len(), self.n_cols, "x length");
        assert_eq!(y.len(), self.n_rows, "y length");
        let mut acc = [0.0 as Value; MAX_C];
        for q in 0..self.n_chunks() {
            let rows = self.chunk_rows(q);
            let base = q * self.c;
            let off = self.chunk_off[q];
            let width = self.chunk_width[q];
            let lens = &self.row_len[base..base + rows];
            let min_len = lens.iter().copied().min().unwrap_or(0) as usize;
            acc[..rows].fill(0.0);
            for k in 0..min_len {
                let p = off + k * rows;
                let vals = &self.values[p..p + rows];
                let cols = &self.col_idx[p..p + rows];
                for i in 0..rows {
                    acc[i] += vals[i] * x[cols[i] as usize];
                }
            }
            for k in min_len..width {
                let p = off + k * rows;
                for i in 0..rows {
                    if (k as Index) < lens[i] {
                        acc[i] += self.values[p + i] * x[self.col_idx[p + i] as usize];
                    }
                }
            }
            for i in 0..rows {
                y[self.perm[base + i] as usize] = acc[i];
            }
        }
    }

    fn kind(&self) -> FormatKind {
        FormatKind::Sell
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Csr;
    use crate::transform::crs_to_sell_with;

    fn sample_csr() -> Csr {
        Csr::from_triplets(
            5,
            5,
            &[
                (0, 0, 1.0),
                (0, 2, 2.0),
                (0, 4, 3.0),
                (1, 1, 4.0),
                (2, 0, 5.0),
                (2, 3, 6.0),
                (4, 4, 7.0),
            ],
        )
        .unwrap()
    }

    #[test]
    fn chunk_layout_and_counts() {
        let a = sample_csr();
        let s = crs_to_sell_with(&a, 2, 2).unwrap();
        assert_eq!(s.c, 2);
        assert_eq!(s.n_chunks(), 3);
        assert_eq!(s.chunk_rows(2), 1, "tail chunk is short");
        assert_eq!(s.nnz(), a.nnz());
        // Window 0 = rows {0,1} sorted desc by length -> slot order [0, 1].
        assert_eq!(&s.perm[..2], &[0, 1]);
        // Chunk 0 width is row 0's length.
        assert_eq!(s.chunk_width[0], 3);
    }

    #[test]
    fn spmv_bitwise_matches_csr() {
        let a = sample_csr();
        let x = [1.5, -2.0, 0.25, 3.0, -0.5];
        let mut want = vec![0.0; 5];
        a.spmv(&x, &mut want);
        for (c, sigma) in [(1, 1), (2, 2), (2, 4), (4, 5), (32, 5)] {
            let s = crs_to_sell_with(&a, c, sigma).unwrap();
            let mut got = vec![0.0; 5];
            s.spmv(&x, &mut got);
            assert_eq!(got, want, "C={c} sigma={sigma}");
        }
    }

    #[test]
    fn fill_ratio_guards_degenerate_inputs() {
        // Empty matrix and all-zero-row matrices report exactly 1.0 (no
        // NaN into the D_mat-R model).
        let empty = crs_to_sell_with(&Csr::from_triplets(0, 0, &[]).unwrap(), 4, 4).unwrap();
        assert_eq!(empty.fill_ratio(), 1.0);
        assert_eq!(empty.padded_slots(), 0);
        let hollow = crs_to_sell_with(&Csr::from_triplets(7, 7, &[]).unwrap(), 4, 4).unwrap();
        assert_eq!(hollow.fill_ratio(), 1.0);
        assert!(hollow.fill_ratio().is_finite());
    }

    #[test]
    fn sigma_window_reduces_padding() {
        // Alternating long/short rows: with sigma=1 (no sort) every
        // 2-chunk pairs a long row with a short one; sigma=4 groups the
        // long rows together, shrinking the padded span.
        let mut t = Vec::new();
        for i in 0..8usize {
            t.push((i, 0, 1.0));
            if i % 2 == 0 {
                for j in 1..4usize {
                    t.push((i, j, 1.0));
                }
            }
        }
        let a = Csr::from_triplets(8, 8, &t).unwrap();
        let unsorted = crs_to_sell_with(&a, 2, 1).unwrap();
        let sorted = crs_to_sell_with(&a, 2, 4).unwrap();
        assert!(sorted.padded_slots() < unsorted.padded_slots());
        assert_eq!(sorted.nnz(), unsorted.nnz());
    }

    #[test]
    fn invalid_inputs_rejected() {
        // C out of range.
        assert!(SellCSigma::new(0, 0, 0, 1, vec![], vec![], vec![], vec![], vec![], vec![])
            .is_err());
        assert!(SellCSigma::new(
            0,
            0,
            MAX_C + 1,
            1,
            vec![],
            vec![],
            vec![],
            vec![],
            vec![],
            vec![]
        )
        .is_err());
        // Not a permutation.
        assert!(SellCSigma::new(
            2,
            2,
            2,
            1,
            vec![1],
            vec![0],
            vec![0, 0],
            vec![1, 1],
            vec![1.0, 1.0],
            vec![0, 0]
        )
        .is_err());
        // row_len exceeding chunk width.
        assert!(SellCSigma::new(
            2,
            2,
            2,
            1,
            vec![1],
            vec![0],
            vec![0, 1],
            vec![2, 1],
            vec![1.0, 1.0],
            vec![0, 0]
        )
        .is_err());
    }

    #[test]
    fn memory_accounts_every_array() {
        let s = crs_to_sell_with(&sample_csr(), 2, 2).unwrap();
        let expect = s.values.len() * 8
            + s.col_idx.len() * 4
            + s.perm.len() * 4
            + s.row_len.len() * 4
            + (s.chunk_width.len() + s.chunk_off.len()) * std::mem::size_of::<usize>();
        assert_eq!(s.memory_bytes(), expect);
    }
}
