//! Hybrid ELL + COO storage (HYB) — the second extension experiment.
//!
//! The paper's §4.5 failure mode is a handful of pathological rows
//! inflating the ELL bandwidth (memplus: μ = 7.1 but max row 574). HYB
//! caps the ELL part at a threshold bandwidth `k` and spills the excess
//! entries of long rows into a COO tail: the bulk of the matrix keeps
//! ELL's regular vector/VMEM-friendly layout while the tail — a tiny
//! fraction of nnz — runs through the scatter path. The threshold is
//! chosen to minimise modelled cost: slots are only worth padding while
//! the padded-slot count grows slower than the spilled-entry count
//! (the classic HYB heuristic, cf. cuSPARSE).

use super::{FormatKind, SparseMatrix};
use crate::formats::{Coo, CooOrder, Csr, Ell};
use crate::{Result, Value};

/// HYB sparse matrix: an ELL body plus a COO-Row tail.
#[derive(Clone, Debug, PartialEq)]
pub struct Hyb {
    /// The regular body (bandwidth = chosen threshold).
    pub ell: Ell,
    /// Spill entries of rows longer than the threshold.
    pub tail: Coo,
}

impl Hyb {
    /// Pick the threshold bandwidth that minimises `slots + spill·w`,
    /// where `w` weights how much more a scatter-path entry costs than a
    /// regular slot (vector machines: ~4–8; we use 4).
    pub fn choose_threshold(a: &Csr) -> usize {
        const SPILL_WEIGHT: f64 = 4.0;
        let n = a.n_rows();
        let max_len = a.max_row_len();
        if n == 0 || max_len == 0 {
            return 0;
        }
        // hist[l] = number of rows with length >= l.
        let mut ge = vec![0usize; max_len + 2];
        for i in 0..n {
            ge[a.row_len(i)] += 1;
        }
        for l in (0..=max_len).rev() {
            ge[l] += ge[l + 1];
        }
        // spill(k) = sum_{l>k} (l - k) * count(l) = sum_{j>k} ge[j].
        let mut spill = vec![0usize; max_len + 2];
        for k in (0..=max_len).rev() {
            spill[k] = spill[k + 1] + ge[k + 1];
        }
        let mut best = (f64::INFINITY, max_len);
        for k in 1..=max_len {
            let cost = (n * k) as f64 + SPILL_WEIGHT * spill[k] as f64;
            if cost < best.0 {
                best = (cost, k);
            }
        }
        best.1
    }

    /// Build from CSR with an explicit threshold.
    pub fn from_csr_with_threshold(a: &Csr, k: usize) -> Result<Self> {
        let n = a.n_rows();
        let k = k.min(a.max_row_len());
        let mut values = vec![0.0 as Value; n * k];
        let mut col_idx = vec![0 as crate::Index; n * k];
        let mut body_nnz = 0usize;
        let mut tail: Vec<(usize, usize, Value)> = Vec::new();
        for i in 0..n {
            for (slot, (c, v)) in a.row(i).enumerate() {
                if slot < k {
                    values[slot * n + i] = v;
                    col_idx[slot * n + i] = c;
                    body_nnz += 1;
                } else {
                    tail.push((i, c as usize, v));
                }
            }
        }
        let ell = Ell::new(n, a.n_cols(), k, values, col_idx, body_nnz)?;
        let tail = Coo::from_triplets(n, a.n_cols(), &tail, CooOrder::RowMajor)?;
        Ok(Self { ell, tail })
    }

    /// Build from CSR with the auto-chosen threshold.
    pub fn from_csr(a: &Csr) -> Result<Self> {
        Self::from_csr_with_threshold(a, Self::choose_threshold(a))
    }

    /// The chosen ELL bandwidth.
    pub fn threshold(&self) -> usize {
        self.ell.bandwidth
    }

    /// Fraction of nnz living in the COO tail.
    pub fn spill_fraction(&self) -> f64 {
        let total = self.nnz();
        if total == 0 {
            0.0
        } else {
            self.tail.nnz() as f64 / total as f64
        }
    }
}

impl SparseMatrix for Hyb {
    fn n_rows(&self) -> usize {
        self.ell.n_rows()
    }

    fn n_cols(&self) -> usize {
        self.ell.n_cols()
    }

    fn nnz(&self) -> usize {
        self.ell.nnz() + self.tail.nnz()
    }

    fn memory_bytes(&self) -> usize {
        self.ell.memory_bytes() + self.tail.memory_bytes()
    }

    /// Body sweep (ELL) + tail scatter (COO), accumulated.
    fn spmv(&self, x: &[Value], y: &mut [Value]) {
        self.ell.spmv(x, y);
        for e in 0..self.tail.nnz() {
            let r = self.tail.row_idx[e] as usize;
            let c = self.tail.col_idx[e] as usize;
            y[r] += self.tail.values[e] * x[c];
        }
    }

    fn kind(&self) -> FormatKind {
        FormatKind::Hyb
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixgen::{banded_circulant, generate, random_csr, spec_by_name};
    use crate::rng::Rng;

    #[test]
    fn spmv_matches_csr_on_random_matrices() {
        let mut rng = Rng::new(71);
        for _ in 0..10 {
            let nr = rng.range(1, 70);
            let nc = rng.range(1, 70);
            let a = random_csr(&mut rng, nr, nc, 0.2);
            let h = Hyb::from_csr(&a).unwrap();
            assert_eq!(h.nnz(), a.nnz());
            let x: Vec<Value> = (0..nc).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let mut want = vec![0.0; nr];
            let mut got = vec![0.0; nr];
            a.spmv(&x, &mut want);
            h.spmv(&x, &mut got);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn memplus_spills_the_tail_and_shrinks_storage() {
        let spec = spec_by_name("memplus").unwrap();
        let a = generate(&spec, 5, 0.03);
        let h = Hyb::from_csr(&a).unwrap();
        let ell = crate::transform::crs_to_ell(&a).unwrap();
        // Threshold far below the full bandwidth, small spill fraction,
        // storage an order of magnitude below pure ELL.
        assert!(h.threshold() < ell.bandwidth / 4, "threshold {}", h.threshold());
        assert!(h.spill_fraction() < 0.35, "spill {}", h.spill_fraction());
        assert!(h.memory_bytes() * 4 < ell.memory_bytes());
    }

    #[test]
    fn perfect_band_has_empty_tail() {
        let mut rng = Rng::new(72);
        let a = banded_circulant(&mut rng, 64, &[-1, 0, 1]);
        let h = Hyb::from_csr(&a).unwrap();
        assert_eq!(h.threshold(), 3);
        assert_eq!(h.tail.nnz(), 0);
        assert_eq!(h.spill_fraction(), 0.0);
    }

    #[test]
    fn explicit_threshold_respected() {
        let mut rng = Rng::new(73);
        let a = random_csr(&mut rng, 40, 40, 0.3);
        let h = Hyb::from_csr_with_threshold(&a, 2).unwrap();
        assert_eq!(h.threshold(), 2);
        assert_eq!(h.ell.nnz() + h.tail.nnz(), a.nnz());
        let x = vec![1.0; 40];
        let mut want = vec![0.0; 40];
        let mut got = vec![0.0; 40];
        a.spmv(&x, &mut want);
        h.spmv(&x, &mut got);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12);
        }
    }

    #[test]
    fn empty_matrix() {
        let a = Csr::from_triplets(4, 4, &[]).unwrap();
        let h = Hyb::from_csr(&a).unwrap();
        assert_eq!(h.nnz(), 0);
        let mut y = vec![9.0; 4];
        h.spmv(&[0.0; 4], &mut y);
        assert_eq!(y, vec![0.0; 4]);
    }
}
