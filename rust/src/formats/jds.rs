//! Jagged Diagonal Storage (JDS) — the classic vector-machine sparse
//! format, implemented as an extension experiment.
//!
//! The paper's ELL results die on high-`D_mat` matrices because padding
//! inflates both storage and compute (memplus: fill ≈ 80×). JDS is the
//! historical fix on exactly the paper's target machine class (it was
//! designed for the Cray/NEC vector pipeline): rows are sorted by
//! descending population and stored as *jagged diagonals* — the k-th
//! stored element of every row long enough to have one. Every diagonal is
//! a dense unit-stride vector of length = (number of rows with ≥ k+1
//! entries), so the SpMV vectorises like ELL **without any zero fill**.
//! The price is a row permutation on `y` and one extra indirection.
//!
//! The `ablation` bench quantifies this: on the ES2 model JDS recovers
//! most of the vector win for memplus where ELL loses to COO.

use super::{FormatKind, SparseMatrix};
use crate::formats::Csr;
use crate::{Index, Result, Value};

/// JDS sparse matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Jds {
    n_rows: usize,
    n_cols: usize,
    /// `perm[i]` = original row index of sorted position `i` (rows sorted
    /// by descending length).
    pub perm: Vec<Index>,
    /// Start offset of each jagged diagonal, length `n_diags + 1`.
    pub jd_ptr: Vec<usize>,
    /// Values, diagonal-major.
    pub values: Vec<Value>,
    /// Column indices, diagonal-major.
    pub col_idx: Vec<Index>,
}

impl Jds {
    /// Build from CSR (stable counting sort by row length, then diagonal
    /// gather — O(n + nnz)).
    pub fn from_csr(a: &Csr) -> Self {
        let n = a.n_rows();
        let max_len = a.max_row_len();
        // Counting sort rows by length, descending, stable.
        let mut count = vec![0usize; max_len + 2];
        for i in 0..n {
            count[a.row_len(i)] += 1;
        }
        // Positions for descending order: longest first.
        let mut start = vec![0usize; max_len + 2];
        let mut acc = 0usize;
        for len in (0..=max_len).rev() {
            start[len] = acc;
            acc += count[len];
        }
        let mut perm = vec![0 as Index; n];
        for i in 0..n {
            let len = a.row_len(i);
            perm[start[len]] = i as Index;
            start[len] += 1;
        }
        // Number of rows with length > k = length of diagonal k.
        let n_diags = max_len;
        let mut jd_ptr = Vec::with_capacity(n_diags + 1);
        jd_ptr.push(0usize);
        let mut diag_len = vec![0usize; n_diags];
        for i in 0..n {
            let l = a.row_len(i);
            for d in diag_len.iter_mut().take(l) {
                *d += 1;
            }
        }
        for k in 0..n_diags {
            jd_ptr.push(jd_ptr[k] + diag_len[k]);
        }
        let nnz = a.nnz();
        debug_assert_eq!(jd_ptr[n_diags], nnz);
        let mut values = vec![0.0 as Value; nnz];
        let mut col_idx = vec![0 as Index; nnz];
        for (pos, &orig) in perm.iter().enumerate() {
            for (k, (c, v)) in a.row(orig as usize).enumerate() {
                // Sorted-descending rows guarantee `pos` is inside
                // diagonal k's range whenever row has a k-th element.
                let off = jd_ptr[k] + pos;
                values[off] = v;
                col_idx[off] = c;
            }
        }
        Self { n_rows: n, n_cols: a.n_cols(), perm, jd_ptr, values, col_idx }
    }

    /// Number of jagged diagonals (= max row length).
    pub fn n_diags(&self) -> usize {
        self.jd_ptr.len() - 1
    }

    /// Length of diagonal `k`.
    pub fn diag_len(&self, k: usize) -> usize {
        self.jd_ptr[k + 1] - self.jd_ptr[k]
    }

    /// SpMV with a caller-provided permuted scratch buffer (`yp.len() >=
    /// n_rows`), avoiding the per-call allocation of the trait method.
    pub fn spmv_into(&self, x: &[Value], y: &mut [Value], yp: &mut [Value]) {
        assert_eq!(x.len(), self.n_cols, "x length");
        assert_eq!(y.len(), self.n_rows, "y length");
        assert!(yp.len() >= self.n_rows, "scratch too small");
        let yp = &mut yp[..self.n_rows];
        yp.fill(0.0);
        // Accumulate in permuted order, then scatter once at the end —
        // keeps the inner loops unit-stride (the vector-machine schedule).
        for k in 0..self.n_diags() {
            let lo = self.jd_ptr[k];
            let len = self.diag_len(k);
            let vals = &self.values[lo..lo + len];
            let cols = &self.col_idx[lo..lo + len];
            for ((ypi, &v), &c) in yp.iter_mut().zip(vals).zip(cols) {
                *ypi += v * x[c as usize];
            }
        }
        for (pos, &orig) in self.perm.iter().enumerate() {
            y[orig as usize] = yp[pos];
        }
    }
}

impl SparseMatrix for Jds {
    fn n_rows(&self) -> usize {
        self.n_rows
    }

    fn n_cols(&self) -> usize {
        self.n_cols
    }

    fn nnz(&self) -> usize {
        self.values.len()
    }

    fn memory_bytes(&self) -> usize {
        self.values.len() * std::mem::size_of::<Value>()
            + self.col_idx.len() * std::mem::size_of::<Index>()
            + self.perm.len() * std::mem::size_of::<Index>()
            + self.jd_ptr.len() * std::mem::size_of::<usize>()
    }

    /// Diagonal-sweep SpMV: each diagonal is a dense unit-stride vector op
    /// accumulating into the permuted prefix of `y`. Allocates the
    /// permuted scratch internally; hot paths use [`Jds::spmv_into`] with
    /// a reused buffer (perf pass, EXPERIMENTS.md §Perf).
    fn spmv(&self, x: &[Value], y: &mut [Value]) {
        let mut yp = vec![0.0 as Value; self.n_rows];
        self.spmv_into(x, y, &mut yp);
    }

    fn kind(&self) -> FormatKind {
        FormatKind::Jds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixgen::{generate, random_csr, spec_by_name};
    use crate::rng::Rng;

    #[test]
    fn spmv_matches_csr_on_random_matrices() {
        let mut rng = Rng::new(61);
        for _ in 0..10 {
            let nr = rng.range(1, 80);
            let nc = rng.range(1, 80);
            let a = random_csr(&mut rng, nr, nc, 0.15);
            let j = Jds::from_csr(&a);
            assert_eq!(j.nnz(), a.nnz());
            let x: Vec<Value> = (0..nc).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let mut want = vec![0.0; nr];
            let mut got = vec![0.0; nr];
            a.spmv(&x, &mut want);
            j.spmv(&x, &mut got);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-12, "{g} vs {w}");
            }
        }
    }

    #[test]
    fn diagonals_are_monotonically_shorter() {
        let spec = spec_by_name("memplus").unwrap();
        let a = generate(&spec, 3, 0.03);
        let j = Jds::from_csr(&a);
        for k in 1..j.n_diags() {
            assert!(j.diag_len(k) <= j.diag_len(k - 1), "diag {k}");
        }
        // First diagonal covers every non-empty row.
        let non_empty = (0..a.n_rows()).filter(|&i| a.row_len(i) > 0).count();
        if j.n_diags() > 0 {
            assert_eq!(j.diag_len(0), non_empty);
        }
    }

    #[test]
    fn no_fill_storage_matches_nnz_exactly() {
        // The whole point vs ELL: memplus-like tails cost nothing extra.
        let spec = spec_by_name("memplus").unwrap();
        let a = generate(&spec, 5, 0.03);
        let j = Jds::from_csr(&a);
        let ell = crate::transform::crs_to_ell(&a).unwrap();
        assert_eq!(j.values.len(), a.nnz());
        assert!(ell.values.len() > 10 * j.values.len(), "ELL fill should dwarf JDS");
    }

    #[test]
    fn perm_is_a_permutation_sorted_by_length() {
        let mut rng = Rng::new(62);
        let a = random_csr(&mut rng, 50, 50, 0.1);
        let j = Jds::from_csr(&a);
        let mut seen = vec![false; 50];
        let mut last_len = usize::MAX;
        for &p in &j.perm {
            assert!(!seen[p as usize], "duplicate in perm");
            seen[p as usize] = true;
            let l = a.row_len(p as usize);
            assert!(l <= last_len, "perm not sorted by descending length");
            last_len = l;
        }
        assert!(seen.into_iter().all(|b| b));
    }

    #[test]
    fn empty_and_degenerate() {
        let a = Csr::from_triplets(3, 3, &[]).unwrap();
        let j = Jds::from_csr(&a);
        assert_eq!(j.n_diags(), 0);
        let mut y = vec![1.0; 3];
        j.spmv(&[0.0; 3], &mut y);
        assert_eq!(y, vec![0.0; 3]);
    }
}
