//! Coordinate storage — the paper's COO format, in row-major (COO-Row) or
//! column-major (COO-Column) entry order.
//!
//! The paper distinguishes the two orders because they admit different
//! OpenMP parallelisations (Figs. 1 and 2): the entry stream is split into
//! `[ISTART(k), IEND(k)]` chunks per thread and each thread accumulates into
//! a private `YY(:,k)` copy that is reduced afterwards.

use super::{check_triplets, FormatKind, SparseMatrix};
use crate::{Index, Result, Value};

/// Entry ordering of a [`Coo`] matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CooOrder {
    /// Entries sorted by (row, col) — the paper's COO-Row.
    RowMajor,
    /// Entries sorted by (col, row) — the paper's COO-Column.
    ColMajor,
}

/// COO sparse matrix: parallel arrays `row_idx`/`col_idx`/`values`
/// (the paper's `IROW`/`ICOL`/`VAL`), sorted according to [`CooOrder`].
#[derive(Clone, Debug, PartialEq)]
pub struct Coo {
    n_rows: usize,
    n_cols: usize,
    /// `IROW` — row index per entry.
    pub row_idx: Vec<Index>,
    /// `ICOL` — column index per entry.
    pub col_idx: Vec<Index>,
    /// `VAL` — value per entry.
    pub values: Vec<Value>,
    order: CooOrder,
}

impl Coo {
    /// Build from raw arrays; verifies bounds and the claimed ordering.
    pub fn new(
        n_rows: usize,
        n_cols: usize,
        row_idx: Vec<Index>,
        col_idx: Vec<Index>,
        values: Vec<Value>,
        order: CooOrder,
    ) -> Result<Self> {
        anyhow::ensure!(
            row_idx.len() == values.len() && col_idx.len() == values.len(),
            "COO array length mismatch: rows {} cols {} vals {}",
            row_idx.len(),
            col_idx.len(),
            values.len()
        );
        for (&r, &c) in row_idx.iter().zip(&col_idx) {
            anyhow::ensure!(
                (r as usize) < n_rows && (c as usize) < n_cols,
                "entry ({r},{c}) out of bounds for {n_rows}x{n_cols}"
            );
        }
        let sorted = match order {
            CooOrder::RowMajor => row_idx
                .windows(2)
                .zip(col_idx.windows(2))
                .all(|(r, c)| (r[0], c[0]) <= (r[1], c[1])),
            CooOrder::ColMajor => col_idx
                .windows(2)
                .zip(row_idx.windows(2))
                .all(|(c, r)| (c[0], r[0]) <= (c[1], r[1])),
        };
        anyhow::ensure!(sorted, "COO entries not sorted for {order:?}");
        Ok(Self { n_rows, n_cols, row_idx, col_idx, values, order })
    }

    /// Build from triplets in the requested order (duplicates summed).
    pub fn from_triplets(
        n_rows: usize,
        n_cols: usize,
        triplets: &[(usize, usize, Value)],
        order: CooOrder,
    ) -> Result<Self> {
        check_triplets(n_rows, n_cols, triplets)?;
        let mut entries = triplets.to_vec();
        match order {
            CooOrder::RowMajor => entries.sort_unstable_by_key(|&(r, c, _)| (r, c)),
            CooOrder::ColMajor => entries.sort_unstable_by_key(|&(r, c, _)| (c, r)),
        }
        let mut merged: Vec<(usize, usize, Value)> = Vec::with_capacity(entries.len());
        for (r, c, v) in entries {
            match merged.last_mut() {
                Some(&mut (lr, lc, ref mut lv)) if lr == r && lc == c => *lv += v,
                _ => merged.push((r, c, v)),
            }
        }
        let mut row_idx = Vec::with_capacity(merged.len());
        let mut col_idx = Vec::with_capacity(merged.len());
        let mut values = Vec::with_capacity(merged.len());
        for (r, c, v) in merged {
            row_idx.push(r as Index);
            col_idx.push(c as Index);
            values.push(v);
        }
        Self::new(n_rows, n_cols, row_idx, col_idx, values, order)
    }

    /// Entry ordering.
    pub fn order(&self) -> CooOrder {
        self.order
    }

    /// Construct without the O(nnz) validation passes — for transforms
    /// whose output is sorted/in-bounds *by construction* (perf pass,
    /// EXPERIMENTS.md §Perf). Invariants are still checked in debug builds.
    pub(crate) fn from_parts_unchecked(
        n_rows: usize,
        n_cols: usize,
        row_idx: Vec<Index>,
        col_idx: Vec<Index>,
        values: Vec<Value>,
        order: CooOrder,
    ) -> Self {
        debug_assert!(Self::new(
            n_rows,
            n_cols,
            row_idx.clone(),
            col_idx.clone(),
            values.clone(),
            order
        )
        .is_ok());
        Self { n_rows, n_cols, row_idx, col_idx, values, order }
    }
}

impl SparseMatrix for Coo {
    fn n_rows(&self) -> usize {
        self.n_rows
    }

    fn n_cols(&self) -> usize {
        self.n_cols
    }

    fn nnz(&self) -> usize {
        self.values.len()
    }

    fn memory_bytes(&self) -> usize {
        self.values.len() * std::mem::size_of::<Value>()
            + (self.row_idx.len() + self.col_idx.len()) * std::mem::size_of::<Index>()
    }

    /// Sequential entry-stream SpMV (order-independent).
    fn spmv(&self, x: &[Value], y: &mut [Value]) {
        assert_eq!(x.len(), self.n_cols, "x length");
        assert_eq!(y.len(), self.n_rows, "y length");
        y.fill(0.0);
        for k in 0..self.values.len() {
            let r = self.row_idx[k] as usize;
            let c = self.col_idx[k] as usize;
            y[r] += self.values[k] * x[c];
        }
    }

    fn kind(&self) -> FormatKind {
        match self.order {
            CooOrder::RowMajor => FormatKind::CooRow,
            CooOrder::ColMajor => FormatKind::CooCol,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const T: [(usize, usize, Value); 5] =
        [(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0), (2, 0, 4.0), (2, 2, 5.0)];

    #[test]
    fn row_major_ordering() {
        let a = Coo::from_triplets(3, 3, &T, CooOrder::RowMajor).unwrap();
        assert_eq!(a.row_idx, vec![0, 0, 1, 2, 2]);
        assert_eq!(a.col_idx, vec![0, 2, 1, 0, 2]);
        assert_eq!(a.kind(), FormatKind::CooRow);
    }

    #[test]
    fn col_major_ordering() {
        let a = Coo::from_triplets(3, 3, &T, CooOrder::ColMajor).unwrap();
        assert_eq!(a.col_idx, vec![0, 0, 1, 2, 2]);
        assert_eq!(a.row_idx, vec![0, 2, 1, 0, 2]);
        assert_eq!(a.kind(), FormatKind::CooCol);
    }

    #[test]
    fn spmv_same_result_both_orders() {
        let x = [1.0, 2.0, 3.0];
        let mut y1 = vec![0.0; 3];
        let mut y2 = vec![0.0; 3];
        Coo::from_triplets(3, 3, &T, CooOrder::RowMajor)
            .unwrap()
            .spmv(&x, &mut y1);
        Coo::from_triplets(3, 3, &T, CooOrder::ColMajor)
            .unwrap()
            .spmv(&x, &mut y2);
        assert_eq!(y1, vec![7.0, 6.0, 19.0]);
        assert_eq!(y1, y2);
    }

    #[test]
    fn unsorted_input_rejected_by_new() {
        let r = Coo::new(2, 2, vec![1, 0], vec![0, 0], vec![1.0, 1.0], CooOrder::RowMajor);
        assert!(r.is_err());
    }

    #[test]
    fn duplicates_summed() {
        let a =
            Coo::from_triplets(2, 2, &[(1, 1, 2.0), (1, 1, 3.0)], CooOrder::RowMajor).unwrap();
        assert_eq!(a.nnz(), 1);
        assert_eq!(a.values, vec![5.0]);
    }
}
