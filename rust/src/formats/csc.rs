//! Compressed Column Storage — the paper's CCS, the Phase-I intermediate of
//! the column-wise run-time transformation (§2.1).

use super::{FormatKind, SparseMatrix};
use crate::{Index, Result, Value};

/// CCS/CSC sparse matrix: column `j`'s entries live in
/// `values[col_ptr[j]..col_ptr[j+1]]` with row indices in `row_idx`.
#[derive(Clone, Debug, PartialEq)]
pub struct Csc {
    n_rows: usize,
    n_cols: usize,
    /// Column start offsets, length `n_cols + 1`.
    pub col_ptr: Vec<usize>,
    /// Row index per stored entry.
    pub row_idx: Vec<Index>,
    /// Value per stored entry.
    pub values: Vec<Value>,
}

impl Csc {
    /// Build from raw arrays, validating the CSC invariants.
    pub fn new(
        n_rows: usize,
        n_cols: usize,
        col_ptr: Vec<usize>,
        row_idx: Vec<Index>,
        values: Vec<Value>,
    ) -> Result<Self> {
        anyhow::ensure!(
            col_ptr.len() == n_cols + 1,
            "col_ptr length {} != n_cols+1 {}",
            col_ptr.len(),
            n_cols + 1
        );
        anyhow::ensure!(col_ptr[0] == 0, "col_ptr[0] != 0");
        anyhow::ensure!(
            row_idx.len() == values.len(),
            "row_idx/values length mismatch"
        );
        anyhow::ensure!(
            *col_ptr.last().unwrap() == values.len(),
            "col_ptr[n] != nnz"
        );
        for w in col_ptr.windows(2) {
            anyhow::ensure!(w[0] <= w[1], "col_ptr not monotone");
        }
        for &r in &row_idx {
            anyhow::ensure!((r as usize) < n_rows, "row {r} out of bounds {n_rows}");
        }
        Ok(Self { n_rows, n_cols, col_ptr, row_idx, values })
    }

    /// Number of stored entries in column `j`.
    #[inline]
    pub fn col_len(&self, j: usize) -> usize {
        self.col_ptr[j + 1] - self.col_ptr[j]
    }

    /// Iterator over `(row, value)` pairs of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> impl Iterator<Item = (Index, Value)> + '_ {
        let lo = self.col_ptr[j];
        let hi = self.col_ptr[j + 1];
        self.row_idx[lo..hi]
            .iter()
            .copied()
            .zip(self.values[lo..hi].iter().copied())
    }

    /// Extract triplets sorted column-major.
    pub fn to_triplets_col_major(&self) -> Vec<(usize, usize, Value)> {
        let mut out = Vec::with_capacity(self.nnz());
        for j in 0..self.n_cols {
            for (r, v) in self.col(j) {
                out.push((r as usize, j, v));
            }
        }
        out
    }
}

impl SparseMatrix for Csc {
    fn n_rows(&self) -> usize {
        self.n_rows
    }

    fn n_cols(&self) -> usize {
        self.n_cols
    }

    fn nnz(&self) -> usize {
        self.values.len()
    }

    fn memory_bytes(&self) -> usize {
        self.values.len() * std::mem::size_of::<Value>()
            + self.row_idx.len() * std::mem::size_of::<Index>()
            + self.col_ptr.len() * std::mem::size_of::<usize>()
    }

    /// Column-wise SpMV: scatter `x[j] * col_j(A)` into `y`.
    fn spmv(&self, x: &[Value], y: &mut [Value]) {
        assert_eq!(x.len(), self.n_cols, "x length");
        assert_eq!(y.len(), self.n_rows, "y length");
        y.fill(0.0);
        for j in 0..self.n_cols {
            let xj = x[j];
            if xj == 0.0 {
                continue;
            }
            for (r, v) in self.col(j) {
                y[r as usize] += v * xj;
            }
        }
    }

    fn kind(&self) -> FormatKind {
        FormatKind::Csc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Csr;
    use crate::transform::crs_to_ccs;

    #[test]
    fn spmv_matches_csr() {
        let a = Csr::from_triplets(
            3,
            4,
            &[(0, 0, 1.0), (0, 3, 2.0), (1, 1, 3.0), (2, 0, 4.0), (2, 2, 5.0)],
        )
        .unwrap();
        let c = crs_to_ccs(&a);
        let x = [1.0, 2.0, 3.0, 4.0];
        let mut y1 = vec![0.0; 3];
        let mut y2 = vec![0.0; 3];
        a.spmv(&x, &mut y1);
        c.spmv(&x, &mut y2);
        assert_eq!(y1, y2);
        assert_eq!(c.kind(), FormatKind::Csc);
    }

    #[test]
    fn invalid_inputs_rejected() {
        assert!(Csc::new(2, 2, vec![0, 1], vec![0], vec![1.0]).is_err());
        assert!(Csc::new(2, 2, vec![0, 2, 1], vec![0, 1], vec![1.0, 1.0]).is_err());
        assert!(Csc::new(2, 2, vec![0, 1, 2], vec![0, 7], vec![1.0, 1.0]).is_err());
    }

    #[test]
    fn col_iteration() {
        let a = Csr::from_triplets(2, 2, &[(0, 0, 1.0), (1, 0, 2.0), (1, 1, 3.0)]).unwrap();
        let c = crs_to_ccs(&a);
        assert_eq!(c.col_len(0), 2);
        assert_eq!(c.col_len(1), 1);
        let col0: Vec<_> = c.col(0).collect();
        assert_eq!(col0, vec![(0, 1.0), (1, 2.0)]);
    }
}
