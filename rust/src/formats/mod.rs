//! Sparse matrix storage formats.
//!
//! The paper (§2.1) works with four formats:
//!
//! * **CRS** (Compressed Row Storage; here [`Csr`] using the modern name) —
//!   `VAL(1:nnz)`, `ICOL(1:nnz)`, `IRP(1:n+1)`. The input format of the
//!   library and of OpenATLib's `OpenATI_DURMV`.
//! * **CCS** (Compressed Column Storage; [`Csc`]) — the Phase-I intermediate
//!   of the column-wise transformation.
//! * **COO** ([`Coo`]) — `VAL/ICOL/IROW(1:nnz)`, in row-major
//!   ([`CooOrder::RowMajor`]) or column-major ([`CooOrder::ColMajor`]) entry
//!   order; the order determines which parallel SpMV (Fig. 1 vs Fig. 2)
//!   applies.
//! * **ELL** ([`Ell`]) — `VAL(1:n,1:nz)` band-major (Fortran column-major)
//!   storage padded with explicit zeros, the format the paper's headline
//!   151x vector-machine speedup comes from.
//!
//! [`Bcsr`] (register-blocked CSR) is implemented as the paper's named
//! future-work extension, [`SellCSigma`] (SELL-C-σ: sliced ELL with
//! σ-window row sorting) is the SIMD-lane growth of ELL, and [`Dense`]
//! exists as a correctness oracle.

mod bcsr;
mod coo;
mod hyb;
mod jds;
mod csc;
mod csr;
mod dense;
mod ell;
mod sell;

pub use bcsr::Bcsr;
pub use coo::{Coo, CooOrder};
pub use csc::Csc;
pub use csr::{Csr, Triangular};
pub use dense::Dense;
pub use hyb::Hyb;
pub use jds::Jds;
pub use ell::Ell;
pub use sell::{SellCSigma, MAX_C};

use crate::{Index, Value};

/// The format tags the auto-tuner switches between (paper §2–§3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FormatKind {
    /// Compressed row storage — the baseline input format.
    Csr,
    /// Compressed column storage (paper: CCS) — transformation intermediate.
    Csc,
    /// Coordinate storage, row-major entry order.
    CooRow,
    /// Coordinate storage, column-major entry order.
    CooCol,
    /// ELLPACK/ITPACK, band-major padded storage.
    Ell,
    /// Register-blocked CSR (paper future work).
    Bcsr,
    /// Jagged Diagonal Storage (extension: the historical vector-machine
    /// format; no zero fill).
    Jds,
    /// Hybrid ELL + COO tail (extension: caps the ELL bandwidth, spills
    /// pathological rows).
    Hyb,
    /// SELL-C-σ — sliced ELL: σ-window row sorting, per-chunk padding,
    /// lane-width-C chunked storage (extension: the SIMD-explicit format).
    Sell,
}

impl FormatKind {
    /// All format kinds, in a stable report order.
    pub const ALL: [FormatKind; 9] = [
        FormatKind::Csr,
        FormatKind::Csc,
        FormatKind::CooRow,
        FormatKind::CooCol,
        FormatKind::Ell,
        FormatKind::Bcsr,
        FormatKind::Jds,
        FormatKind::Hyb,
        FormatKind::Sell,
    ];

    /// Short, stable display name used by reports and the CLI.
    pub fn name(self) -> &'static str {
        match self {
            FormatKind::Csr => "CRS",
            FormatKind::Csc => "CCS",
            FormatKind::CooRow => "COO-Row",
            FormatKind::CooCol => "COO-Col",
            FormatKind::Ell => "ELL",
            FormatKind::Bcsr => "BCSR",
            FormatKind::Jds => "JDS",
            FormatKind::Hyb => "HYB",
            FormatKind::Sell => "SELL",
        }
    }

    /// Parse the name emitted by [`FormatKind::name`] (case-insensitive).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "crs" | "csr" => Some(FormatKind::Csr),
            "ccs" | "csc" => Some(FormatKind::Csc),
            "coo-row" | "coorow" | "coo_row" => Some(FormatKind::CooRow),
            "coo-col" | "coocol" | "coo_col" => Some(FormatKind::CooCol),
            "ell" => Some(FormatKind::Ell),
            "bcsr" => Some(FormatKind::Bcsr),
            "jds" => Some(FormatKind::Jds),
            "hyb" => Some(FormatKind::Hyb),
            "sell" | "sell-c-s" | "sellcsigma" => Some(FormatKind::Sell),
            _ => None,
        }
    }
}

impl std::fmt::Display for FormatKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Common behaviour across sparse formats: shape, nnz, memory footprint and
/// a sequential `y = A·x`.
pub trait SparseMatrix {
    /// Number of rows.
    fn n_rows(&self) -> usize;
    /// Number of columns.
    fn n_cols(&self) -> usize;
    /// Number of *stored* non-zero entries (for ELL this excludes padding).
    fn nnz(&self) -> usize;
    /// Storage footprint in bytes (values + index arrays), the quantity the
    /// memory auto-tuning policy (paper §2.2) budgets.
    fn memory_bytes(&self) -> usize;
    /// Sequential sparse matrix-vector product `y = A·x`.
    ///
    /// # Panics
    /// Panics if `x.len() != n_cols()` or `y.len() != n_rows()`.
    fn spmv(&self, x: &[Value], y: &mut [Value]);
    /// The format tag.
    fn kind(&self) -> FormatKind;
}

/// Validate a triplet list against a shape; shared by the `from_triplets`
/// constructors.
pub(crate) fn check_triplets(
    n_rows: usize,
    n_cols: usize,
    triplets: &[(usize, usize, Value)],
) -> crate::Result<()> {
    for &(r, c, _) in triplets {
        anyhow::ensure!(
            r < n_rows && c < n_cols,
            "triplet ({r},{c}) out of bounds for {n_rows}x{n_cols}"
        );
    }
    anyhow::ensure!(
        n_rows <= Index::MAX as usize && n_cols <= Index::MAX as usize,
        "matrix dimensions exceed Index range"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_kind_roundtrip() {
        for k in FormatKind::ALL {
            assert_eq!(FormatKind::parse(k.name()), Some(k), "{k}");
        }
        assert_eq!(FormatKind::parse("nope"), None);
        assert_eq!(FormatKind::parse("csr"), Some(FormatKind::Csr));
        assert_eq!(FormatKind::parse("CSC"), Some(FormatKind::Csc));
    }

    #[test]
    fn check_triplets_bounds() {
        assert!(check_triplets(2, 2, &[(0, 0, 1.0), (1, 1, 1.0)]).is_ok());
        assert!(check_triplets(2, 2, &[(2, 0, 1.0)]).is_err());
        assert!(check_triplets(2, 2, &[(0, 2, 1.0)]).is_err());
    }
}
