//! Compressed Row Storage — the paper's CRS, the library's input format.

use super::{check_triplets, FormatKind, SparseMatrix};
use crate::{Index, Result, Value};

/// CRS/CSR sparse matrix.
///
/// Zero-based equivalent of the paper's `VAL(1:nnz)`, `ICOL(1:nnz)`,
/// `IRP(1:n+1)` arrays: row `i`'s entries live in
/// `values[row_ptr[i]..row_ptr[i+1]]` with matching column indices in
/// `col_idx`.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    n_rows: usize,
    n_cols: usize,
    /// `IRP` — row start offsets, length `n_rows + 1`, monotonically
    /// non-decreasing, `row_ptr[0] == 0`, `row_ptr[n_rows] == nnz`.
    pub row_ptr: Vec<usize>,
    /// `ICOL` — column index per stored entry.
    pub col_idx: Vec<Index>,
    /// `VAL` — value per stored entry.
    pub values: Vec<Value>,
}

impl Csr {
    /// Build from raw arrays, validating the CSR invariants.
    pub fn new(
        n_rows: usize,
        n_cols: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<Index>,
        values: Vec<Value>,
    ) -> Result<Self> {
        anyhow::ensure!(
            row_ptr.len() == n_rows + 1,
            "row_ptr length {} != n_rows+1 {}",
            row_ptr.len(),
            n_rows + 1
        );
        anyhow::ensure!(row_ptr[0] == 0, "row_ptr[0] = {} != 0", row_ptr[0]);
        anyhow::ensure!(
            col_idx.len() == values.len(),
            "col_idx/values length mismatch: {} vs {}",
            col_idx.len(),
            values.len()
        );
        anyhow::ensure!(
            *row_ptr.last().unwrap() == values.len(),
            "row_ptr[n] = {} != nnz = {}",
            row_ptr[n_rows],
            values.len()
        );
        for w in row_ptr.windows(2) {
            anyhow::ensure!(w[0] <= w[1], "row_ptr not monotone: {} > {}", w[0], w[1]);
        }
        for &c in &col_idx {
            anyhow::ensure!((c as usize) < n_cols, "column {c} out of bounds {n_cols}");
        }
        Ok(Self { n_rows, n_cols, row_ptr, col_idx, values })
    }

    /// Build from (row, col, value) triplets. Duplicates are summed, entries
    /// are sorted row-major then by column — the canonical form every
    /// transformation in [`crate::transform`] assumes.
    pub fn from_triplets(
        n_rows: usize,
        n_cols: usize,
        triplets: &[(usize, usize, Value)],
    ) -> Result<Self> {
        check_triplets(n_rows, n_cols, triplets)?;
        let mut entries: Vec<(usize, usize, Value)> = triplets.to_vec();
        entries.sort_unstable_by_key(|&(r, c, _)| (r, c));
        // Sum duplicates in place.
        let mut merged: Vec<(usize, usize, Value)> = Vec::with_capacity(entries.len());
        for (r, c, v) in entries {
            match merged.last_mut() {
                Some(&mut (lr, lc, ref mut lv)) if lr == r && lc == c => *lv += v,
                _ => merged.push((r, c, v)),
            }
        }
        let nnz = merged.len();
        let mut row_ptr = vec![0usize; n_rows + 1];
        for &(r, _, _) in &merged {
            row_ptr[r + 1] += 1;
        }
        for i in 0..n_rows {
            row_ptr[i + 1] += row_ptr[i];
        }
        let mut col_idx = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        for (_, c, v) in merged {
            col_idx.push(c as Index);
            values.push(v);
        }
        Self::new(n_rows, n_cols, row_ptr, col_idx, values)
    }

    /// Identity matrix of order `n`.
    pub fn identity(n: usize) -> Self {
        Self {
            n_rows: n,
            n_cols: n,
            row_ptr: (0..=n).collect(),
            col_idx: (0..n as Index).collect(),
            values: vec![1.0; n],
        }
    }

    /// Number of stored entries in row `i`.
    #[inline]
    pub fn row_len(&self, i: usize) -> usize {
        self.row_ptr[i + 1] - self.row_ptr[i]
    }

    /// Iterator over `(col, value)` pairs of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> impl Iterator<Item = (Index, Value)> + '_ {
        let lo = self.row_ptr[i];
        let hi = self.row_ptr[i + 1];
        self.col_idx[lo..hi]
            .iter()
            .copied()
            .zip(self.values[lo..hi].iter().copied())
    }

    /// Longest row length — the ELL bandwidth `nz` this matrix would need.
    pub fn max_row_len(&self) -> usize {
        (0..self.n_rows).map(|i| self.row_len(i)).max().unwrap_or(0)
    }

    /// Extract triplets (sorted row-major) — used by tests and IO.
    pub fn to_triplets(&self) -> Vec<(usize, usize, Value)> {
        let mut out = Vec::with_capacity(self.nnz());
        for i in 0..self.n_rows {
            for (c, v) in self.row(i) {
                out.push((i, c as usize, v));
            }
        }
        out
    }

    /// Transpose (CSR of Aᵀ) — an O(nnz) counting pass, the same pattern as
    /// the paper's CRS→CCS transformation.
    pub fn transpose(&self) -> Csr {
        let mut cnt = vec![0usize; self.n_cols + 1];
        for &c in &self.col_idx {
            cnt[c as usize + 1] += 1;
        }
        for j in 0..self.n_cols {
            cnt[j + 1] += cnt[j];
        }
        let mut row_ptr = cnt.clone();
        let mut col_idx = vec![0 as Index; self.nnz()];
        let mut values = vec![0.0; self.nnz()];
        for i in 0..self.n_rows {
            for (c, v) in self.row(i) {
                let slot = cnt[c as usize];
                cnt[c as usize] += 1;
                col_idx[slot] = i as Index;
                values[slot] = v;
            }
        }
        row_ptr[self.n_cols] = self.nnz();
        Csr {
            n_rows: self.n_cols,
            n_cols: self.n_rows,
            row_ptr,
            col_idx,
            values,
        }
    }

    /// `y = Aᵀ·x` without materialising the transpose.
    pub fn spmv_transpose(&self, x: &[Value], y: &mut [Value]) {
        assert_eq!(x.len(), self.n_rows);
        assert_eq!(y.len(), self.n_cols);
        y.fill(0.0);
        for i in 0..self.n_rows {
            let xi = x[i];
            for (c, v) in self.row(i) {
                y[c as usize] += v * xi;
            }
        }
    }

    /// The contiguous row block `rows` as its own CSR matrix (column
    /// width unchanged, row indices rebased to the block). This is the
    /// cross-socket SpMM split's building block: each shard plans and
    /// streams only its row block, and `y[rows]` of the full product is
    /// exactly the block's product — per-row accumulation order is
    /// untouched, so a row-split execution is bitwise-identical to the
    /// unsplit one for the row-oriented kernels.
    ///
    /// # Panics
    /// Panics if `rows.end > n_rows` or `rows.start > rows.end`.
    pub fn slice_rows(&self, rows: std::ops::Range<usize>) -> Csr {
        assert!(rows.start <= rows.end && rows.end <= self.n_rows, "bad row range {rows:?}");
        let lo = self.row_ptr[rows.start];
        let hi = self.row_ptr[rows.end];
        Csr {
            n_rows: rows.end - rows.start,
            n_cols: self.n_cols,
            row_ptr: self.row_ptr[rows.start..=rows.end].iter().map(|p| p - lo).collect(),
            col_idx: self.col_idx[lo..hi].to_vec(),
            values: self.values[lo..hi].to_vec(),
        }
    }

    /// Split a square matrix into its strictly-lower triangle `L`, its
    /// diagonal `D`, and its strictly-upper triangle `U` — the
    /// decomposition every triangular-solve / Gauss-Seidel kernel in
    /// [`crate::precond`] consumes. One O(nnz) pass; per-row entry order
    /// is preserved in both triangles, so for a canonical (column-sorted)
    /// CSR the split is exactly reversible: [`Triangular::recompose`]
    /// rebuilds the original matrix entry for entry, including
    /// explicitly-stored zero diagonal entries (tracked separately from
    /// absent ones) and empty rows.
    pub fn split_triangular(&self) -> Result<Triangular> {
        anyhow::ensure!(
            self.n_rows == self.n_cols,
            "split_triangular needs a square matrix, got {}x{}",
            self.n_rows,
            self.n_cols
        );
        let n = self.n_rows;
        let mut lo = TriBuilder::new(n);
        let mut up = TriBuilder::new(n);
        let mut diag = vec![0.0; n];
        let mut diag_stored = vec![false; n];
        for i in 0..n {
            for (c, v) in self.row(i) {
                let j = c as usize;
                match j.cmp(&i) {
                    std::cmp::Ordering::Less => lo.push(c, v),
                    std::cmp::Ordering::Greater => up.push(c, v),
                    std::cmp::Ordering::Equal => {
                        diag[i] = v;
                        diag_stored[i] = true;
                    }
                }
            }
            lo.end_row();
            up.end_row();
        }
        Ok(Triangular {
            lower: lo.finish(n),
            diag,
            diag_stored,
            upper: up.finish(n),
        })
    }

    /// Check structural invariants (used by property tests / debug assertions).
    pub fn validate(&self) -> Result<()> {
        let _ = Self::new(
            self.n_rows,
            self.n_cols,
            self.row_ptr.clone(),
            self.col_idx.clone(),
            self.values.clone(),
        )?;
        Ok(())
    }
}

impl SparseMatrix for Csr {
    fn n_rows(&self) -> usize {
        self.n_rows
    }

    fn n_cols(&self) -> usize {
        self.n_cols
    }

    fn nnz(&self) -> usize {
        self.values.len()
    }

    fn memory_bytes(&self) -> usize {
        self.values.len() * std::mem::size_of::<Value>()
            + self.col_idx.len() * std::mem::size_of::<Index>()
            + self.row_ptr.len() * std::mem::size_of::<usize>()
    }

    /// The OpenATLib `OpenATI_DURMV` switch-11 baseline: a plain row loop.
    /// The inner loop walks zipped value/column slices so the compiler can
    /// elide the per-element bounds checks (perf pass, EXPERIMENTS.md §Perf).
    fn spmv(&self, x: &[Value], y: &mut [Value]) {
        assert_eq!(x.len(), self.n_cols, "x length");
        assert_eq!(y.len(), self.n_rows, "y length");
        for i in 0..self.n_rows {
            let lo = self.row_ptr[i];
            let hi = self.row_ptr[i + 1];
            let acc: Value = self.values[lo..hi]
                .iter()
                .zip(&self.col_idx[lo..hi])
                .map(|(&v, &c)| v * x[c as usize])
                .sum();
            y[i] = acc;
        }
    }

    fn kind(&self) -> FormatKind {
        FormatKind::Csr
    }
}

/// Incremental CSR assembly for [`Csr::split_triangular`]: entries are
/// appended in the source matrix's own order, so no re-sort can disturb
/// per-row entry order.
struct TriBuilder {
    row_ptr: Vec<usize>,
    col_idx: Vec<Index>,
    values: Vec<Value>,
}

impl TriBuilder {
    fn new(n: usize) -> Self {
        Self {
            row_ptr: {
                let mut v = Vec::with_capacity(n + 1);
                v.push(0);
                v
            },
            col_idx: Vec::new(),
            values: Vec::new(),
        }
    }

    fn push(&mut self, c: Index, v: Value) {
        self.col_idx.push(c);
        self.values.push(v);
    }

    fn end_row(&mut self) {
        self.row_ptr.push(self.col_idx.len());
    }

    fn finish(self, n: usize) -> Csr {
        // Invariants hold by construction (in-bounds cols, monotone ptr).
        Csr {
            n_rows: n,
            n_cols: n,
            row_ptr: self.row_ptr,
            col_idx: self.col_idx,
            values: self.values,
        }
    }
}

/// The `A = L + D + U` decomposition of a square CSR matrix
/// ([`Csr::split_triangular`]): strictly-lower and strictly-upper
/// triangles as their own CSR matrices plus the dense diagonal.
///
/// `diag[i]` is 0.0 both for an absent diagonal entry and for an
/// explicitly-stored zero; `diag_stored` disambiguates, which is what
/// makes [`Triangular::recompose`] exact (same nnz, same entries) rather
/// than merely numerically equal.
///
/// The triangles are *strict*: solvers that want a unit diagonal
/// (`(I + L)·x = b`) pass `diag: None` to the [`crate::precond::sptrsv`]
/// kernels, and solvers that want `(D + L)·x = b` pass `Some(&diag)` —
/// the unit-diagonal "view" is a kernel argument, not a copy.
#[derive(Clone, Debug, PartialEq)]
pub struct Triangular {
    /// Strictly-lower triangle (entries with `col < row`).
    pub lower: Csr,
    /// Diagonal values, dense (0.0 where no entry is stored).
    pub diag: Vec<Value>,
    /// Whether row `i` stores an explicit diagonal entry (distinguishes
    /// a stored zero from an absent entry, for exact recomposition).
    pub diag_stored: Vec<bool>,
    /// Strictly-upper triangle (entries with `col > row`).
    pub upper: Csr,
}

impl Triangular {
    /// Matrix order.
    pub fn n(&self) -> usize {
        self.diag.len()
    }

    /// Stored diagonal entries (≤ n).
    pub fn diag_nnz(&self) -> usize {
        self.diag_stored.iter().filter(|&&s| s).count()
    }

    /// Whether every diagonal value is non-zero — the invertibility
    /// precondition for `(D + L)` / `(D + U)` triangular solves.
    pub fn diag_nonzero(&self) -> bool {
        self.diag.iter().all(|&v| v != 0.0)
    }

    /// Rebuild the original matrix. Exact for canonical (column-sorted)
    /// input — each row concatenates its lower entries, its stored
    /// diagonal entry (if any), then its upper entries, which is
    /// precisely the order a sorted row was split in.
    pub fn recompose(&self) -> Csr {
        let n = self.n();
        let nnz = self.lower.nnz() + self.upper.nnz() + self.diag_nnz();
        let mut b = TriBuilder::new(n);
        b.col_idx.reserve(nnz);
        b.values.reserve(nnz);
        for i in 0..n {
            for (c, v) in self.lower.row(i) {
                b.push(c, v);
            }
            if self.diag_stored[i] {
                b.push(i as Index, self.diag[i]);
            }
            for (c, v) in self.upper.row(i) {
                b.push(c, v);
            }
            b.end_row();
        }
        b.finish(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [[1 0 2]
        //  [0 3 0]
        //  [4 0 5]]
        Csr::from_triplets(
            3,
            3,
            &[(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0), (2, 0, 4.0), (2, 2, 5.0)],
        )
        .unwrap()
    }

    #[test]
    fn from_triplets_builds_canonical_csr() {
        let a = sample();
        assert_eq!(a.row_ptr, vec![0, 2, 3, 5]);
        assert_eq!(a.col_idx, vec![0, 2, 1, 0, 2]);
        assert_eq!(a.values, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        a.validate().unwrap();
    }

    #[test]
    fn duplicates_are_summed() {
        let a = Csr::from_triplets(2, 2, &[(0, 0, 1.0), (0, 0, 2.5)]).unwrap();
        assert_eq!(a.nnz(), 1);
        assert_eq!(a.values, vec![3.5]);
    }

    #[test]
    fn spmv_matches_hand_computation() {
        let a = sample();
        let mut y = vec![0.0; 3];
        a.spmv(&[1.0, 2.0, 3.0], &mut y);
        assert_eq!(y, vec![7.0, 6.0, 19.0]);
    }

    #[test]
    fn split_triangular_partitions_the_sample() {
        let a = sample();
        let t = a.split_triangular().unwrap();
        // Strict triangles: only (2,0) below, only (0,2) above.
        assert_eq!(t.lower.nnz(), 1);
        assert_eq!(t.lower.row(2).collect::<Vec<_>>(), vec![(0, 4.0)]);
        assert_eq!(t.upper.nnz(), 1);
        assert_eq!(t.upper.row(0).collect::<Vec<_>>(), vec![(2, 2.0)]);
        assert_eq!(t.diag, vec![1.0, 3.0, 5.0]);
        assert_eq!(t.diag_stored, vec![true, true, true]);
        assert!(t.diag_nonzero());
        t.lower.validate().unwrap();
        t.upper.validate().unwrap();
    }

    #[test]
    fn split_recompose_is_exact() {
        let a = sample();
        assert_eq!(a.split_triangular().unwrap().recompose(), a);
    }

    #[test]
    fn split_tracks_stored_zero_diagonal_and_empty_rows() {
        // Row 0: explicit zero diagonal. Row 1: empty. Row 2: no
        // diagonal entry at all. from_triplets keeps explicit zeros.
        let a = Csr::from_triplets(3, 3, &[(0, 0, 0.0), (2, 0, 7.0)]).unwrap();
        let t = a.split_triangular().unwrap();
        assert_eq!(t.diag, vec![0.0, 0.0, 0.0]);
        assert_eq!(t.diag_stored, vec![true, false, false]);
        assert_eq!(t.diag_nnz(), 1);
        assert!(!t.diag_nonzero());
        // Exact recomposition distinguishes the stored zero from the
        // absent entries: same nnz, same structure, same values.
        let back = t.recompose();
        assert_eq!(back, a);
        assert_eq!(back.nnz(), 2);
    }

    #[test]
    fn split_rejects_rectangular() {
        let a = Csr::from_triplets(2, 3, &[(0, 0, 1.0)]).unwrap();
        assert!(a.split_triangular().is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let a = sample();
        let att = a.transpose().transpose();
        assert_eq!(a, att);
    }

    #[test]
    fn spmv_transpose_matches_materialized() {
        let a = sample();
        let x = [1.0, -2.0, 0.5];
        let mut y1 = vec![0.0; 3];
        let mut y2 = vec![0.0; 3];
        a.spmv_transpose(&x, &mut y1);
        a.transpose().spmv(&x, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn row_iteration_and_lengths() {
        let a = sample();
        assert_eq!(a.row_len(0), 2);
        assert_eq!(a.row_len(1), 1);
        assert_eq!(a.max_row_len(), 2);
        let r0: Vec<_> = a.row(0).collect();
        assert_eq!(r0, vec![(0, 1.0), (2, 2.0)]);
    }

    #[test]
    fn invalid_inputs_rejected() {
        assert!(Csr::new(2, 2, vec![0, 1], vec![0], vec![1.0]).is_err()); // short row_ptr
        assert!(Csr::new(2, 2, vec![0, 2, 1], vec![0, 1], vec![1.0, 1.0]).is_err()); // non-monotone
        assert!(Csr::new(2, 2, vec![0, 1, 2], vec![0, 5], vec![1.0, 1.0]).is_err()); // col oob
        assert!(Csr::new(2, 2, vec![0, 1, 1], vec![0], vec![1.0, 2.0]).is_err()); // len mismatch
    }

    #[test]
    fn empty_rows_and_empty_matrix() {
        let a = Csr::from_triplets(3, 3, &[(1, 1, 2.0)]).unwrap();
        assert_eq!(a.row_len(0), 0);
        assert_eq!(a.row_len(2), 0);
        let e = Csr::from_triplets(0, 0, &[]).unwrap();
        assert_eq!(e.nnz(), 0);
        let mut y = vec![];
        e.spmv(&[], &mut y);
    }

    #[test]
    fn identity_spmv_is_copy() {
        let a = Csr::identity(4);
        let x = [9.0, 8.0, 7.0, 6.0];
        let mut y = vec![0.0; 4];
        a.spmv(&x, &mut y);
        assert_eq!(y, x);
    }

    #[test]
    fn slice_rows_rebases_and_covers() {
        let a = sample();
        let s = a.slice_rows(1..3);
        assert_eq!(s.n_rows(), 2);
        assert_eq!(s.n_cols(), 3);
        assert_eq!(s.row_ptr, vec![0, 1, 3]);
        s.validate().unwrap();
        // Block SpMV equals the matching rows of the full product.
        let x = [1.0, 2.0, 3.0];
        let mut full = vec![0.0; 3];
        a.spmv(&x, &mut full);
        let mut part = vec![0.0; 2];
        s.spmv(&x, &mut part);
        assert_eq!(part, full[1..3]);
        // Degenerate slices.
        assert_eq!(a.slice_rows(0..0).nnz(), 0);
        assert_eq!(a.slice_rows(0..3), a);
    }

    #[test]
    fn to_triplets_roundtrip() {
        let t = vec![(0usize, 0usize, 1.0), (0, 2, 2.0), (1, 1, 3.0), (2, 0, 4.0), (2, 2, 5.0)];
        let a = Csr::from_triplets(3, 3, &t).unwrap();
        assert_eq!(a.to_triplets(), t);
    }
}
