"""L2 model + AOT pipeline tests: bucket lowering produces valid HLO text,
the manifest matches, and the lowered computation is numerically identical
to the Pallas kernel it wraps."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


SMALL_BUCKETS = [(256, 4), (256, 8)]


def _random_bucket_inputs(rows, bandwidth, seed=0):
    rng = np.random.default_rng(seed)
    values = rng.standard_normal((bandwidth, rows))
    col_idx = rng.integers(0, rows, (bandwidth, rows), dtype=np.int32)
    x = rng.standard_normal(rows)
    return values, col_idx, x


def test_model_matches_ref_for_buckets():
    for rows, bandwidth in SMALL_BUCKETS:
        values, col_idx, x = _random_bucket_inputs(rows, bandwidth, rows)
        (got,) = model.ell_spmv_model(
            jnp.asarray(values), jnp.asarray(col_idx), jnp.asarray(x)
        )
        want = ref.ell_spmv_ref(jnp.asarray(values), jnp.asarray(col_idx), jnp.asarray(x))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


def test_power_iteration_model_converges_to_dominant_eigvec():
    # Diagonal matrix as a 1-band ELL: dominant eigenvector is e_argmax.
    rows = 256
    diag = np.linspace(1.0, 2.0, rows)
    values = diag[None, :]
    col_idx = np.arange(rows, dtype=np.int32)[None, :]
    x0 = np.ones(rows) / np.sqrt(rows)
    (v,) = model.ell_power_iteration_model(
        jnp.asarray(values), jnp.asarray(col_idx), jnp.asarray(x0), iters=200
    )
    v = np.asarray(v)
    assert np.argmax(np.abs(v)) == rows - 1
    np.testing.assert_allclose(np.linalg.norm(v), 1.0, rtol=1e-6)


def test_lower_bucket_produces_hlo_text():
    text = aot.lower_bucket(256, 4)
    assert "HloModule" in text
    assert "ENTRY" in text
    # f64 data path survived lowering.
    assert "f64" in text


def test_emit_writes_manifest_and_files(tmp_path):
    out = str(tmp_path / "artifacts")
    rows = aot.emit(out, SMALL_BUCKETS, verbose=False)
    assert len(rows) == len(SMALL_BUCKETS)
    manifest = open(os.path.join(out, "manifest.tsv")).read().strip().splitlines()
    data_lines = [l for l in manifest if not l.startswith("#")]
    assert len(data_lines) == len(SMALL_BUCKETS)
    for line, (r, b) in zip(data_lines, SMALL_BUCKETS):
        kind, rr, bb, fname = line.split("\t")
        assert kind == "ell_spmv"
        assert (int(rr), int(bb)) == (r, b)
        path = os.path.join(out, fname)
        assert os.path.exists(path)
        assert "HloModule" in open(path).read()[:4096]


def test_parse_buckets():
    assert aot.parse_buckets("1024x8,4096x16") == [(1024, 8), (4096, 16)]
    assert aot.parse_buckets("256X4") == [(256, 4)]
    with pytest.raises(ValueError):
        aot.parse_buckets("garbage")


def test_bucket_args_shapes():
    v, c, x = model.bucket_args(1024, 8)
    assert v.shape == (8, 1024)
    assert c.shape == (8, 1024)
    assert x.shape == (1024,)
    assert v.dtype == jnp.float64
    assert c.dtype == jnp.int32


def test_default_buckets_are_block_aligned():
    from compile.kernels.ell_spmv import BLOCK_ROWS

    for rows, bandwidth in model.BUCKETS:
        assert rows % BLOCK_ROWS == 0, (rows, bandwidth)
        assert bandwidth >= 1
