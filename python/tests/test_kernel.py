"""L1 kernel correctness: Pallas ELL SpMV vs the pure-jnp oracle.

Hypothesis sweeps shapes and data (including degenerate bands, zero
matrices, and duplicate column indices), asserting allclose at f64
precision — the core correctness signal before anything is AOT-shipped
to the rust runtime.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ell_spmv as ek
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def random_ell(rng, n, nz, n_cols, density=0.7, dtype=np.float64):
    """Random band-major ELL arrays with realistic padding."""
    values = np.zeros((nz, n), dtype=dtype)
    col_idx = np.zeros((nz, n), dtype=np.int32)
    for i in range(n):
        # Row population: 0..nz entries, padding after.
        pop = rng.binomial(nz, density)
        cols = rng.choice(n_cols, size=pop, replace=False) if pop else []
        for k, c in enumerate(sorted(cols)):
            values[k, i] = rng.standard_normal()
            col_idx[k, i] = c
    return values, col_idx


def dense_spmv(values, col_idx, x):
    """Dense-matrix oracle, fully independent of jnp gather semantics."""
    nz, n = values.shape
    y = np.zeros(n, dtype=values.dtype)
    for i in range(n):
        for k in range(nz):
            y[i] += values[k, i] * x[col_idx[k, i]]
    return y


@pytest.mark.parametrize("n,nz", [(128, 1), (128, 4), (256, 7), (384, 16)])
def test_pallas_matches_ref_fixed_shapes(n, nz):
    rng = np.random.default_rng(n * 31 + nz)
    values, col_idx = random_ell(rng, n, nz, n)
    x = rng.standard_normal(n)
    got = np.asarray(ek.ell_spmv(jnp.asarray(values), jnp.asarray(col_idx), jnp.asarray(x)))
    want = np.asarray(ref.ell_spmv_ref(jnp.asarray(values), jnp.asarray(col_idx), jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(got, dense_spmv(values, col_idx, x), rtol=1e-10, atol=1e-10)


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=3),
    nz=st.integers(min_value=1, max_value=9),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    density=st.floats(min_value=0.0, max_value=1.0),
)
def test_pallas_matches_dense_hypothesis(blocks, nz, seed, density):
    n = blocks * ek.BLOCK_ROWS
    rng = np.random.default_rng(seed)
    values, col_idx = random_ell(rng, n, nz, n, density=density)
    x = rng.standard_normal(n)
    got = np.asarray(ek.ell_spmv(jnp.asarray(values), jnp.asarray(col_idx), jnp.asarray(x)))
    np.testing.assert_allclose(got, dense_spmv(values, col_idx, x), rtol=1e-10, atol=1e-10)


def test_zero_matrix_gives_zero():
    n, nz = 128, 3
    values = jnp.zeros((nz, n))
    col_idx = jnp.zeros((nz, n), dtype=jnp.int32)
    x = jnp.ones((n,))
    y = ek.ell_spmv(values, col_idx, x)
    np.testing.assert_array_equal(np.asarray(y), np.zeros(n))


def test_identity_band():
    n = 256
    values = jnp.ones((1, n))
    col_idx = jnp.arange(n, dtype=jnp.int32)[None, :]
    x = jnp.asarray(np.random.default_rng(0).standard_normal(n))
    y = ek.ell_spmv(values, col_idx, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-15)


def test_duplicate_columns_sum():
    # Two bands pointing at the same column must add (CSR duplicate-sum
    # convention carried through the transform).
    n = 128
    values = jnp.full((2, n), 1.5)
    col_idx = jnp.zeros((2, n), dtype=jnp.int32)
    x = jnp.asarray(np.arange(n, dtype=np.float64) + 1.0)
    y = ek.ell_spmv(values, col_idx, x)
    np.testing.assert_allclose(np.asarray(y), np.full(n, 3.0 * 1.0), rtol=1e-15)


def test_rejects_non_divisible_block():
    values = jnp.zeros((2, 100))
    col_idx = jnp.zeros((2, 100), dtype=jnp.int32)
    x = jnp.zeros((100,))
    with pytest.raises(ValueError, match="not divisible"):
        ek.ell_spmv(values, col_idx, x)


def test_float32_dtype_supported():
    n, nz = 128, 4
    rng = np.random.default_rng(7)
    values, col_idx = random_ell(rng, n, nz, n, dtype=np.float32)
    x = rng.standard_normal(n).astype(np.float32)
    got = np.asarray(
        ek.ell_spmv(jnp.asarray(values), jnp.asarray(col_idx), jnp.asarray(x))
    )
    assert got.dtype == np.float32
    np.testing.assert_allclose(
        got, dense_spmv(values.astype(np.float64), col_idx, x.astype(np.float64)),
        rtol=1e-5, atol=1e-5,
    )


def test_coo_ref_matches_dense():
    rng = np.random.default_rng(11)
    n, nnz = 60, 300
    rows = rng.integers(0, n, nnz)
    cols = rng.integers(0, n, nnz)
    vals = rng.standard_normal(nnz)
    x = rng.standard_normal(n)
    got = np.asarray(
        ref.coo_spmv_ref(
            jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x), n
        )
    )
    want = np.zeros(n)
    for r, c, v in zip(rows, cols, vals):
        want[r] += v * x[c]
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)


def test_vmem_estimate_monotone():
    base = ek.vmem_bytes(8, 128, 1024)
    assert ek.vmem_bytes(16, 128, 1024) > base
    assert ek.vmem_bytes(8, 256, 1024) > base
    # Utilization = 1/fill.
    assert ek.utilization_estimate(100, 10, 500) == pytest.approx(0.5)
    assert ek.utilization_estimate(100, 10, 1000) == pytest.approx(1.0)


# ---- x-tiled variant ----


@pytest.mark.parametrize("n,nz,tile", [(256, 4, 128), (384, 7, 128), (128, 3, 64)])
def test_tiled_x_matches_flat_kernel(n, nz, tile):
    rng = np.random.default_rng(n + nz)
    values, col_idx = random_ell(rng, n, nz, n)
    x = rng.standard_normal(n)
    flat = np.asarray(ek.ell_spmv(jnp.asarray(values), jnp.asarray(col_idx), jnp.asarray(x)))
    tiled = np.asarray(
        ek.ell_spmv_tiled_x(
            jnp.asarray(values), jnp.asarray(col_idx), jnp.asarray(x), tile_cols=tile
        )
    )
    np.testing.assert_allclose(tiled, flat, rtol=1e-12, atol=1e-12)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    nz=st.integers(min_value=1, max_value=6),
)
def test_tiled_x_hypothesis(seed, nz):
    n = 256
    rng = np.random.default_rng(seed)
    values, col_idx = random_ell(rng, n, nz, n, density=0.8)
    x = rng.standard_normal(n)
    got = np.asarray(
        ek.ell_spmv_tiled_x(
            jnp.asarray(values), jnp.asarray(col_idx), jnp.asarray(x), tile_cols=64
        )
    )
    np.testing.assert_allclose(got, dense_spmv(values, col_idx, x), rtol=1e-10, atol=1e-10)


def test_tiled_x_rejects_bad_tile():
    values = jnp.zeros((2, 128))
    col_idx = jnp.zeros((2, 128), dtype=jnp.int32)
    x = jnp.zeros((100,))
    with pytest.raises(ValueError, match="not divisible"):
        ek.ell_spmv_tiled_x(values, col_idx, x, tile_cols=64)


def test_tiled_x_duplicate_columns_accumulate_across_tiles():
    # Entries pointing at columns in different tiles must all contribute.
    n = 128
    values = np.ones((2, n))
    col_idx = np.zeros((2, n), dtype=np.int32)
    col_idx[1, :] = n - 1  # second band points at the last column (tile 2)
    x = np.zeros(n)
    x[0] = 3.0
    x[n - 1] = 5.0
    got = np.asarray(
        ek.ell_spmv_tiled_x(
            jnp.asarray(values), jnp.asarray(col_idx), jnp.asarray(x), tile_cols=64
        )
    )
    np.testing.assert_allclose(got, np.full(n, 8.0))
