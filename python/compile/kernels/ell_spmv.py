"""L1 — the Pallas ELL SpMV kernel (the paper's compute hot-spot).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's win on
the SX-9 comes from turning SpMV into ``nz`` unit-stride vector sweeps of
length ``n`` over the band-major ``VAL(1:n,1:nz)`` array. On TPU the same
regularity maps onto the VPU/MXU through BlockSpec tiling instead of
vector strip-mining:

* the band-major slab ``values[nz, n]`` is tiled into ``(nz, BLOCK_ROWS)``
  VMEM blocks — the HBM->VMEM schedule that threadblock/vector-pipeline
  scheduling did on the paper's machines;
* ``x`` stays fully VMEM-resident per block so the column gather is a
  VMEM-local operation;
* each grid step computes ``BLOCK_ROWS`` outputs with an 8x128-lane
  FMA-reduce over the ``nz`` axis — no per-row control flow, exactly why
  ELL beats CRS on wide-vector hardware;
* ``D_mat`` keeps its meaning: zero-fill inflates the slab by
  ``fill_ratio``, wasting VMEM bandwidth and lanes.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; the interpret path lowers to plain HLO, which is what
``aot.py`` ships to the rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows computed per grid step. 128 matches the TPU lane width and divides
# every AOT bucket size.
BLOCK_ROWS = 128


def _ell_kernel(val_ref, col_ref, x_ref, y_ref):
    """One grid step: y[block] = sum_k val[k, block] * x[col[k, block]]."""
    vals = val_ref[...]  # (nz, BLOCK_ROWS) VMEM slab
    cols = col_ref[...]  # (nz, BLOCK_ROWS)
    x = x_ref[...]  # (n_cols,) VMEM-resident
    # Gather + FMA-reduce across the band axis; no per-row loop.
    y_ref[...] = jnp.sum(vals * x[cols], axis=0)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def ell_spmv(values, col_idx, x, block_rows=BLOCK_ROWS):
    """Band-major ELL SpMV as a Pallas kernel.

    Args:
      values: ``(nz, n)`` float64 band-major ELL values (padding = 0.0).
      col_idx: ``(nz, n)`` int32 column indices (padding = 0).
      x: ``(n_cols,)`` float64 input vector.
      block_rows: rows per grid step; must divide ``n``.

    Returns:
      ``(n,)`` float64 ``y = A @ x``.
    """
    nz, n = values.shape
    if n % block_rows != 0:
        raise ValueError(f"n={n} not divisible by block_rows={block_rows}")
    grid = (n // block_rows,)
    return pl.pallas_call(
        _ell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nz, block_rows), lambda i: (0, i)),
            pl.BlockSpec((nz, block_rows), lambda i: (0, i)),
            pl.BlockSpec(x.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), values.dtype),
        interpret=True,
    )(values, col_idx, x)


def vmem_bytes(nz, block_rows, n_cols, value_bytes=8, index_bytes=4):
    """Estimated VMEM footprint of one grid step (DESIGN.md §Perf L1).

    values block + col block + whole x + y block. The TPU budget is
    ~16 MiB/core; callers use this to pick ``block_rows`` and to reason
    about whether ``x`` residency fits (for huge n, x would need its own
    tiling, turning the gather into a multi-pass exchange).
    """
    return (
        nz * block_rows * value_bytes
        + nz * block_rows * index_bytes
        + n_cols * value_bytes
        + block_rows * value_bytes
    )


def utilization_estimate(n, nz, nnz, block_rows=BLOCK_ROWS):
    """Fraction of FMA lanes doing useful (non-padding) work.

    Equal to ``1 / fill_ratio`` — D_mat's compute-waste interpretation on
    TPU. Returned alongside the VMEM estimate in DESIGN.md §Perf because
    interpret=True wallclock is *not* a TPU proxy; structure is what we
    can optimise.
    """
    slots = n * nz
    return (nnz / slots) if slots else 1.0


# ---------------------------------------------------------------------------
# X-tiled variant: the multi-pass HBM<->VMEM schedule for matrices whose x
# vector does NOT fit in VMEM (n_cols * 8B > ~16 MiB, i.e. n >~ 2M rows).
# The grid gains a leading x-tile axis; each (tile, row-block) step loads
# one x tile, masks the gather to columns inside the tile, and accumulates
# into the revisited y block. This trades `n_tiles` passes over the ELL
# slab for bounded VMEM residency — the TPU analogue of strip-mining the
# paper's vector loop when the gather footprint exceeds the register file.
# ---------------------------------------------------------------------------


def _ell_tiled_kernel(tile_cols, val_ref, col_ref, x_ref, y_ref):
    """One (x-tile, row-block) step with masked gather and accumulation."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    vals = val_ref[...]  # (nz, BLOCK_ROWS)
    cols = col_ref[...]
    x_tile = x_ref[...]  # (tile_cols,)
    lo = t * tile_cols
    in_tile = (cols >= lo) & (cols < lo + tile_cols)
    local = jnp.where(in_tile, cols - lo, 0)
    contrib = jnp.where(in_tile, vals * x_tile[local], 0.0)
    y_ref[...] += jnp.sum(contrib, axis=0)


@functools.partial(jax.jit, static_argnames=("block_rows", "tile_cols"))
def ell_spmv_tiled_x(values, col_idx, x, block_rows=BLOCK_ROWS, tile_cols=BLOCK_ROWS):
    """Band-major ELL SpMV with `x` tiled through VMEM.

    Args:
      values: ``(nz, n)`` float64 band-major ELL values.
      col_idx: ``(nz, n)`` int32 column indices.
      x: ``(n_cols,)`` float64; ``n_cols`` must divide by ``tile_cols``.
      block_rows: rows per grid step (must divide ``n``).
      tile_cols: x-tile width per pass.

    Returns:
      ``(n,)`` float64 ``y = A @ x``.
    """
    nz, n = values.shape
    (n_cols,) = x.shape
    if n % block_rows != 0:
        raise ValueError(f"n={n} not divisible by block_rows={block_rows}")
    if n_cols % tile_cols != 0:
        raise ValueError(f"n_cols={n_cols} not divisible by tile_cols={tile_cols}")
    n_tiles = n_cols // tile_cols
    grid = (n_tiles, n // block_rows)
    return pl.pallas_call(
        functools.partial(_ell_tiled_kernel, tile_cols),
        grid=grid,
        in_specs=[
            pl.BlockSpec((nz, block_rows), lambda t, i: (0, i)),
            pl.BlockSpec((nz, block_rows), lambda t, i: (0, i)),
            pl.BlockSpec((tile_cols,), lambda t, i: (t,)),
        ],
        # y block revisited across the x-tile axis (accumulation).
        out_specs=pl.BlockSpec((block_rows,), lambda t, i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), values.dtype),
        interpret=True,
    )(values, col_idx, x)
