"""Pure-jnp correctness oracles for the SpMV kernels.

These are the ground truth the Pallas kernels (and, transitively, the HLO
artifacts the rust runtime executes) are validated against in pytest.
Layouts mirror the rust library exactly:

* ELL is **band-major**: ``values[k, i]`` is band ``k`` of row ``i``
  (the paper's ``VAL(1:n, 1:nz)`` Fortran column-major array, i.e.
  ``J_PTR = N*(K-1) + I`` addressing). Padding slots carry value 0.0 and
  column 0.
* COO arrives as parallel ``(rows, cols, vals)`` arrays.
"""

import jax.numpy as jnp


def ell_spmv_ref(values, col_idx, x):
    """Reference band-major ELL SpMV.

    Args:
      values: ``(nz, n)`` float array, band-major ELL values.
      col_idx: ``(nz, n)`` int array, column index per slot.
      x: ``(n_cols,)`` float input vector.

    Returns:
      ``(n,)`` output ``y = A @ x``.
    """
    gathered = x[col_idx]  # (nz, n)
    return jnp.sum(values * gathered, axis=0)


def coo_spmv_ref(rows, cols, vals, x, n_rows):
    """Reference COO SpMV via segment-sum scatter-add.

    Args:
      rows: ``(nnz,)`` int row indices.
      cols: ``(nnz,)`` int column indices.
      vals: ``(nnz,)`` float values.
      x: ``(n_cols,)`` float input vector.
      n_rows: static output length.

    Returns:
      ``(n_rows,)`` output ``y = A @ x``.
    """
    contrib = vals * x[cols]
    return jnp.zeros((n_rows,), dtype=vals.dtype).at[rows].add(contrib)


def dense_from_ell(values, col_idx, n_cols):
    """Materialise the dense matrix an ELL pair represents (test helper).

    Padding slots carry value 0.0, so scatter-adding contributes nothing.
    """
    nz, n = values.shape
    dense = jnp.zeros((n, n_cols), dtype=values.dtype)
    for k in range(nz):
        dense = dense.at[jnp.arange(n), col_idx[k]].add(values[k])
    return dense
