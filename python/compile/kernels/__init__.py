"""L1 Pallas kernels for the SpMV hot-spots."""
