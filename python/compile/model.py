"""L2 — the JAX compute graphs the AOT pipeline lowers.

The paper's "model" is the SpMV operator itself plus the iterative-solver
step built on it. Each function here is a pure jax function over one AOT
shape bucket; ``aot.py`` lowers them to HLO text once at build time and
the rust runtime executes them forever after.

Everything returns a 1-tuple — the rust side unwraps with ``to_tuple1()``
(see /opt/xla-example/load_hlo).
"""

import jax
import jax.numpy as jnp

from compile.kernels import ell_spmv as ell_kernel
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def ell_spmv_model(values, col_idx, x):
    """The bucketed ELL SpMV model: calls the L1 Pallas kernel so the
    kernel lowers into the same HLO module."""
    return (ell_kernel.ell_spmv(values, col_idx, x),)


def ell_spmv_ref_model(values, col_idx, x):
    """Pure-jnp variant of the same bucket (ablation artifact: lets the
    rust side A/B the Pallas lowering against XLA's native gather fusion)."""
    return (ref.ell_spmv_ref(values, col_idx, x),)


def ell_power_iteration_model(values, col_idx, x, iters=8):
    """A small end-to-end compute graph: ``iters`` normalised SpMV steps
    (power iteration), demonstrating that a whole solver inner loop — not
    just one SpMV — can ship as a single artifact. Uses ``lax.fori_loop``
    so the unrolled size stays constant."""

    def body(_, v):
        w = ell_kernel.ell_spmv(values, col_idx, v)
        norm = jnp.sqrt(jnp.sum(w * w)) + 1e-300
        return w / norm

    return (jax.lax.fori_loop(0, iters, body, x),)


def bucket_args(rows, bandwidth, n_cols=None):
    """ShapeDtypeStructs for one ``(rows, bandwidth)`` bucket."""
    n_cols = n_cols or rows
    return (
        jax.ShapeDtypeStruct((bandwidth, rows), jnp.float64),
        jax.ShapeDtypeStruct((bandwidth, rows), jnp.int32),
        jax.ShapeDtypeStruct((n_cols,), jnp.float64),
    )


#: The shape buckets shipped as artifacts. Rows are powers of two so the
#: Pallas BLOCK_ROWS=128 tiling divides evenly; bandwidths cover the
#: Table-1 suite at bench scale (larger matrices fall back to the native
#: rust kernels — the coordinator handles that routing).
BUCKETS = [
    (256, 4),
    (256, 8),
    (256, 16),
    (1024, 4),
    (1024, 8),
    (1024, 16),
    (1024, 32),
    (4096, 8),
    (4096, 16),
    (4096, 32),
    (4096, 64),
    (16384, 8),
    (16384, 16),
    (16384, 32),
]
