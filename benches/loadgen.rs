//! loadgen — closed- and open-loop load generator for the network
//! serving front end.
//!
//! Drives a real in-process [`NetServer`] (TCP on an ephemeral port)
//! with concurrent protocol clients and records what a serving operator
//! cares about: p50/p95/p99 request latency, sustained throughput, and
//! the measured coalescing factor (requests per coalescer dispatch) —
//! the number that says how much matrix-streaming the ingress coalescer
//! saved. Closed loop: every client keeps one request in flight, so
//! concurrency = client count. Open loop: a pacer emits request ticks at
//! a target rate and latency is measured from the scheduled tick, so
//! queueing delay under overload is visible instead of being absorbed
//! into a slower offered rate.
//!
//! A third, shed phase drives a dedicated front end with a deliberate
//! coalesce window and expired request deadlines, measuring the
//! drain-time shedding path. The bench also asserts the admission hot
//! path's zero-allocation property: key interns stay bounded by
//! sessions, never by requests.
//!
//! JSON keys consumed by CI: `p50_us`/`p95_us`/`p99_us` and
//! `coalescing_factor` under both loops, plus `deadline_sheds`,
//! `shed_rate`, and `key_interns` (see `.github/workflows/ci.yml`,
//! bench-smoke).

mod common;

use spmv_at::coordinator::{CoordinatorConfig, Server};
use spmv_at::matrixgen::banded_circulant;
use spmv_at::metrics::Json;
use spmv_at::net::proto::{self, WireNetStats};
use spmv_at::net::{ListenAddr, NetClient, NetConfig, NetServer};
use spmv_at::rng::Rng;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// An explicit front-end config — the bench never reads the environment
/// knobs, so its numbers mean the same thing on every machine.
fn net_cfg(coalesce_wait: Duration) -> NetConfig {
    NetConfig {
        queue_depth: 512,
        coalesce_wait,
        auth_token: None,
        quota_requests: 0,
        quota_bytes: 0,
        decision_log: None,
    }
}

fn percentile(sorted_us: &[f64], p: f64) -> f64 {
    if sorted_us.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted_us.len() - 1) as f64).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)]
}

/// Requests-per-dispatch over a counter window.
fn factor(before: &WireNetStats, after: &WireNetStats) -> f64 {
    let batches = after.batches.saturating_sub(before.batches);
    if batches == 0 {
        return 1.0;
    }
    after.requests.saturating_sub(before.requests) as f64 / batches as f64
}

fn latency_obj(mut lats_us: Vec<f64>, wall: Duration, fac: f64) -> Vec<(String, Json)> {
    lats_us.sort_by(|a, b| a.total_cmp(b));
    vec![
        ("requests".into(), Json::Num(lats_us.len() as f64)),
        ("p50_us".into(), Json::Num(percentile(&lats_us, 50.0))),
        ("p95_us".into(), Json::Num(percentile(&lats_us, 95.0))),
        ("p99_us".into(), Json::Num(percentile(&lats_us, 99.0))),
        (
            "throughput_rps".into(),
            Json::Num(lats_us.len() as f64 / wall.as_secs_f64().max(1e-9)),
        ),
        ("coalescing_factor".into(), Json::Num(fac)),
    ]
}

fn main() {
    common::banner("loadgen", "network serving front end: latency percentiles + coalescing");
    let quick = common::quick();

    let n = if quick { 1024 } else { 16384 };
    let clients = if quick { 4 } else { 16 };
    let reqs_per_client = if quick { 25 } else { 400 };
    let open_rate = if quick { 400.0 } else { 2000.0 };
    let open_secs = if quick { 0.5 } else { 5.0 };
    let open_workers = if quick { 4 } else { 16 };

    let tuning = spmv_at::autotune::online::TuningData {
        backend: "sim:ES2".into(),
        imp: spmv_at::spmv::Implementation::EllRowOuter,
        threads: 1,
        c: 1.0,
        d_star: Some(3.1),
    };
    let mut ccfg = CoordinatorConfig::new(tuning.clone());
    // Serving passes only: exploration would add shadow matrix streams
    // and pollute the coalescing accounting.
    ccfg.adaptive.enabled = false;
    let (server, client) = Server::spawn_sharded(ccfg, 64);
    let net = NetServer::start(
        server,
        client,
        &ListenAddr::Tcp("127.0.0.1:0".into()),
        net_cfg(Duration::ZERO),
    )
    .expect("bind an ephemeral port");
    let addr = net.local_addr().clone();

    let mut rng = Rng::new(common::seed());
    let a = banded_circulant(&mut rng, n, &[-2, -1, 0, 1, 2]);
    let mut control = NetClient::connect(&addr).expect("connect control client");
    control.register("m", &a).expect("register bench matrix");
    let x: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64 * 0.125).collect();

    // ---- Closed loop: `clients` connections, one request in flight each.
    println!("closed loop: {clients} client(s) x {reqs_per_client} request(s), n={n}");
    let before = control.net_stats().unwrap();
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|_| {
            let addr = addr.clone();
            let x = x.clone();
            std::thread::spawn(move || {
                let mut c = NetClient::connect(&addr).expect("connect load client");
                let mut lats = Vec::with_capacity(reqs_per_client);
                for _ in 0..reqs_per_client {
                    let t = Instant::now();
                    c.spmv("m", x.clone()).expect("closed-loop request");
                    lats.push(t.elapsed().as_secs_f64() * 1e6);
                }
                lats
            })
        })
        .collect();
    let mut closed_lats = Vec::new();
    for h in handles {
        closed_lats.extend(h.join().expect("closed-loop client"));
    }
    let closed_wall = t0.elapsed();
    let after = control.net_stats().unwrap();
    let closed_factor = factor(&before, &after);
    let closed = latency_obj(closed_lats, closed_wall, closed_factor);
    println!(
        "  p50={:.0}us p95={:.0}us p99={:.0}us factor={closed_factor:.2} wall={:.2}s",
        closed.iter().find(|(k, _)| k == "p50_us").map_or(0.0, |(_, v)| num(v)),
        closed.iter().find(|(k, _)| k == "p95_us").map_or(0.0, |(_, v)| num(v)),
        closed.iter().find(|(k, _)| k == "p99_us").map_or(0.0, |(_, v)| num(v)),
        closed_wall.as_secs_f64()
    );

    // ---- Open loop: paced ticks at a target rate; latency from the
    // scheduled tick, so queueing under overload is charged to requests.
    let total_open = (open_rate * open_secs) as usize;
    println!("open loop: {open_rate:.0} rps target for {open_secs}s ({open_workers} worker(s))");
    let before = control.net_stats().unwrap();
    let (tick_tx, tick_rx) = mpsc::channel::<Instant>();
    let tick_rx = Arc::new(Mutex::new(tick_rx));
    let t0 = Instant::now();
    let workers: Vec<_> = (0..open_workers)
        .map(|_| {
            let addr = addr.clone();
            let x = x.clone();
            let tick_rx = Arc::clone(&tick_rx);
            std::thread::spawn(move || {
                let mut c = NetClient::connect(&addr).expect("connect open-loop client");
                let mut lats = Vec::new();
                loop {
                    let tick = match tick_rx.lock().expect("tick queue").recv() {
                        Ok(t) => t,
                        Err(_) => break,
                    };
                    c.spmv("m", x.clone()).expect("open-loop request");
                    lats.push(tick.elapsed().as_secs_f64() * 1e6);
                }
                lats
            })
        })
        .collect();
    let interval = Duration::from_secs_f64(1.0 / open_rate);
    let start = Instant::now();
    for i in 0..total_open {
        let target = start + interval * i as u32;
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        if tick_tx.send(target).is_err() {
            break;
        }
    }
    drop(tick_tx);
    let mut open_lats = Vec::new();
    for h in workers {
        open_lats.extend(h.join().expect("open-loop worker"));
    }
    let open_wall = t0.elapsed();
    let after = control.net_stats().unwrap();
    let open_factor = factor(&before, &after);
    let open = latency_obj(open_lats, open_wall, open_factor);
    println!(
        "  p50={:.0}us p99={:.0}us factor={open_factor:.2} achieved={:.0} rps",
        open.iter().find(|(k, _)| k == "p50_us").map_or(0.0, |(_, v)| num(v)),
        open.iter().find(|(k, _)| k == "p99_us").map_or(0.0, |(_, v)| num(v)),
        open.iter().find(|(k, _)| k == "throughput_rps").map_or(0.0, |(_, v)| num(v)),
    );

    let stats = control.net_stats().unwrap();

    // ---- Zero-allocation admission check: each session interns a matrix
    // key at most once; every request after that clones the Arc. If a
    // per-request String allocation crept back into `Ingress::submit`,
    // interns would track requests instead of sessions.
    let interns = net.counters().key_interns.load(Ordering::Relaxed);
    println!(
        "key interns: {interns} (sessions={}, coalesced requests={})",
        stats.sessions_total, stats.requests
    );
    assert!(
        interns <= stats.sessions_total,
        "per-request key allocation crept back in: {interns} interns across {} sessions",
        stats.sessions_total
    );
    assert!(
        interns < stats.requests,
        "key interns ({interns}) must stay far below requests ({})",
        stats.requests
    );

    // ---- Shed phase: a dedicated front end with a 5 ms coalesce window
    // (so the latency loops above stay unaffected). Requests alternate
    // between a 1 µs budget — long expired when the drain happens, shed
    // deterministically — and an ample budget that serves normally.
    let shed_n = if quick { 8 } else { 64 };
    println!("shed phase: {shed_n} expired-deadline + {shed_n} live request(s), 5ms window");
    let mut shed_ccfg = CoordinatorConfig::new(tuning);
    shed_ccfg.adaptive.enabled = false;
    let (shed_server, shed_client) = Server::spawn_sharded(shed_ccfg, 64);
    let shed_net = NetServer::start(
        shed_server,
        shed_client,
        &ListenAddr::Tcp("127.0.0.1:0".into()),
        net_cfg(Duration::from_millis(5)),
    )
    .expect("bind shed front end");
    // Deadlines need a v2 session regardless of any SPMV_AT_NET_PROTO
    // override in the environment.
    let mut sc = NetClient::connect_with(shed_net.local_addr(), proto::VERSION, None)
        .expect("connect shed client");
    sc.register("m", &a).expect("register shed matrix");
    let mut shed_hit = 0u64;
    for i in 0..shed_n * 2 {
        if i % 2 == 0 {
            if sc.spmv_deadline("m", x.clone(), 1).is_err() {
                shed_hit += 1;
            }
        } else {
            sc.spmv_deadline("m", x.clone(), 60_000_000).expect("ample budget serves");
        }
    }
    let shed_stats = sc.net_stats().unwrap();
    let shed_rate = shed_hit as f64 / (shed_n * 2) as f64;
    println!(
        "  sheds={} served={} shed_rate={shed_rate:.3}",
        shed_stats.deadline_sheds, shed_stats.requests
    );
    assert!(shed_stats.deadline_sheds >= 1, "the expired deadlines never shed: {shed_stats:?}");
    assert_eq!(shed_stats.deadline_sheds, shed_hit, "every shed surfaced as a client error");

    common::write_json(
        "loadgen",
        Json::Obj(vec![
            ("n".into(), Json::Num(n as f64)),
            ("closed".into(), Json::Obj(closed)),
            (
                "open".into(),
                Json::Obj(
                    [("target_rps".into(), Json::Num(open_rate))]
                        .into_iter()
                        .chain(open)
                        .collect(),
                ),
            ),
            ("sessions_total".into(), Json::Num(stats.sessions_total as f64)),
            ("coalesced_batches".into(), Json::Num(stats.coalesced_batches as f64)),
            ("max_batch".into(), Json::Num(stats.max_batch as f64)),
            ("admission_rejects".into(), Json::Num(stats.admission_rejects as f64)),
            ("key_interns".into(), Json::Num(interns as f64)),
            ("deadline_sheds".into(), Json::Num(shed_stats.deadline_sheds as f64)),
            ("shed_rate".into(), Json::Num(shed_rate)),
        ]),
    );

    drop(sc);
    shed_net.shutdown();
    drop(control);
    net.shutdown();
}

fn num(j: &Json) -> f64 {
    match j {
        Json::Num(v) => *v,
        _ => 0.0,
    }
}
