//! Fig. 7 — `TT_ell`: the CRS→ELL transformation overhead in units of one
//! CRS SpMV, one thread, on both machine stand-ins (plus the host, where
//! the transformation is actually executed rather than modelled).
//!
//! Expected shapes (paper §4.4): on the SR16000, some matrices cost
//! 20×–50× (memplus, sme3Da–c); on the ES2, everything is 0.01×–0.51×.

#[path = "common.rs"]
mod common;

use spmv_at::machine::scalar::ScalarMachine;
use spmv_at::machine::vector::VectorMachine;
use spmv_at::machine::{Backend, MeasuredBackend, SimulatedBackend};
use spmv_at::metrics::{Json, Table};
use spmv_at::spmv::Implementation;

fn main() {
    common::banner("Fig. 7", "TT_ell = t_trans/t_crs at 1 thread");
    let sr = SimulatedBackend::new(ScalarMachine::default());
    let es2 = SimulatedBackend::new(VectorMachine::default());
    let host = MeasuredBackend::new(0, common::reps(3));
    let suite = common::suite();
    let imp = Implementation::EllRowOuter;

    let mut t = Table::new(vec!["no", "matrix", "D_mat", "TT(SR16000)", "TT(ES2)", "TT(host)"]);
    let mut json_rows = Vec::new();
    let mut sr_max: (f64, String) = (0.0, String::new());
    let mut es2_max: f64 = 0.0;
    for (spec, a) in &suite {
        if spec.no == 3 {
            // torso1: ELL excluded for memory overflow, as in the paper.
            continue;
        }
        let tt = |b: &dyn Backend| -> f64 {
            let t_crs = b.spmv_seconds(a, Implementation::CsrSeq, 1).unwrap();
            let t_tr = b.transform_seconds(a, imp).unwrap();
            t_tr / t_crs
        };
        let tt_sr = tt(&sr);
        let tt_es2 = tt(&es2);
        // Host: skip the transform measurement for the very large matrices
        // to keep the bench fast; the simulated columns carry the figure.
        let tt_host = if a.nnz() < 3_000_000 { tt(&host) } else { f64::NAN };
        if tt_sr > sr_max.0 {
            sr_max = (tt_sr, spec.name.to_string());
        }
        es2_max = es2_max.max(tt_es2);
        t.row(vec![
            spec.no.to_string(),
            spec.name.to_string(),
            format!("{:.2}", spec.d_mat),
            format!("{tt_sr:.2}"),
            format!("{tt_es2:.3}"),
            if tt_host.is_nan() { "-".into() } else { format!("{tt_host:.2}") },
        ]);
        json_rows.push(Json::Obj(vec![
            ("matrix".into(), Json::Str(spec.name.into())),
            ("tt_sr16000".into(), Json::Num(tt_sr)),
            ("tt_es2".into(), Json::Num(tt_es2)),
            ("tt_host".into(), Json::Num(tt_host)),
        ]));
    }
    print!("{}", t.render());
    println!(
        "\nSR16000 max TT = {:.1}x on {} (paper: 20x-50x for memplus & sme3D*)",
        sr_max.0, sr_max.1
    );
    println!("ES2 max TT = {es2_max:.3}x (paper: 0.01x-0.51x)");
    common::write_json("fig7_overhead", Json::Arr(json_rows));
}

use spmv_at::formats::SparseMatrix as _;
