//! Table 1 — the test-matrix suite: published `(N, NNZ, μ, σ, D_mat)` vs
//! the synthetically regenerated matrices' measured statistics.
//!
//! The paper's Table 1 defines the suite every other experiment runs on;
//! this bench proves the synthetic stand-ins hit the published moments
//! (and therefore the same AT decision boundary).

#[path = "common.rs"]
mod common;

use spmv_at::matrixgen::measure;
use spmv_at::metrics::{Json, Table};

fn main() {
    common::banner("Table 1", "test matrices — published spec vs generated");
    let suite = common::suite();
    let mut t = Table::new(vec![
        "no", "name", "set", "field", "N", "NNZ", "mu(pub)", "mu(gen)", "sig(pub)", "sig(gen)",
        "D(pub)", "D(gen)", "bw(gen)",
    ]);
    let mut rows = Vec::new();
    for (spec, a) in &suite {
        let m = measure(a);
        t.row(vec![
            spec.no.to_string(),
            spec.name.to_string(),
            if spec.set == 1 { "I".into() } else { "II".to_string() },
            spec.field.to_string(),
            m.n.to_string(),
            m.nnz.to_string(),
            format!("{:.2}", spec.mu),
            format!("{:.2}", m.mu),
            format!("{:.2}", spec.sigma),
            format!("{:.2}", m.sigma),
            format!("{:.2}", spec.d_mat),
            format!("{:.2}", m.d_mat),
            m.max_row.to_string(),
        ]);
        rows.push(Json::Obj(vec![
            ("no".into(), Json::Num(spec.no as f64)),
            ("name".into(), Json::Str(spec.name.into())),
            ("n".into(), Json::Num(m.n as f64)),
            ("nnz".into(), Json::Num(m.nnz as f64)),
            ("mu_pub".into(), Json::Num(spec.mu)),
            ("mu_gen".into(), Json::Num(m.mu)),
            ("sigma_pub".into(), Json::Num(spec.sigma)),
            ("sigma_gen".into(), Json::Num(m.sigma)),
            ("d_pub".into(), Json::Num(spec.d_mat)),
            ("d_gen".into(), Json::Num(m.d_mat)),
            ("bandwidth".into(), Json::Num(m.max_row as f64)),
        ]));
    }
    print!("{}", t.render());
    // Shape check the paper relies on: torso1 (no. 3) must be the ELL
    // memory blow-up case; report its predicted ELL footprint.
    if let Some((spec, a)) = suite.iter().find(|(s, _)| s.no == 3) {
        let shape = spmv_at::machine::MatrixShape::of(a);
        let ell_bytes = spmv_at::autotune::MemoryPolicy::predicted_bytes(
            &shape,
            spmv_at::formats::FormatKind::Ell,
        );
        println!(
            "\n{}: predicted ELL storage = {:.2} GiB at this scale (fill {:.1}x) — the paper's \
             'overflow memory space' exclusion",
            spec.name,
            ell_bytes as f64 / (1u64 << 30) as f64,
            shape.fill_ratio
        );
    }
    common::write_json("table1", Json::Arr(rows));
}
