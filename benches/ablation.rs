//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **`D*` rule** — paper-literal max vs conservative prefix vs
//!    power-law-model threshold: leave-one-out decision accuracy over the
//!    suite on both machines (was transforming actually right, judged by
//!    the held-out matrix's own `R`?).
//! 2. **Partition policy** — `split_even` vs `split_by_nnz` load imbalance
//!    across the suite (the reason `csr_row_par` uses nnz balancing).
//! 3. **BCSR extension** — the paper's future-work format vs ELL on the
//!    scalar model.
//! 4. **Parallel transformation** (paper future work) — measured host
//!    speedup of the parallel CRS→ELL/CCS over the sequential §2.1 code.

#[path = "common.rs"]
mod common;

use spmv_at::autotune::{run_offline, OfflineConfig};
use spmv_at::formats::Csr;
use spmv_at::machine::scalar::ScalarMachine;
use spmv_at::machine::vector::VectorMachine;
use spmv_at::machine::{Backend, SimulatedBackend};
use spmv_at::metrics::{time_median, Json, Table};
use spmv_at::spmv::partition::{imbalance, split_by_nnz, split_even};
use spmv_at::spmv::Implementation;
use spmv_at::transform;

/// Ablation 1: leave-one-out accuracy of the three D* rules.
fn ablate_dstar(backend: &dyn Backend, suite: &[(String, Csr)]) -> (f64, f64, f64) {
    let cfg = OfflineConfig::default();
    let full = run_offline(backend, suite, &cfg).expect("offline");
    // Ground truth per matrix: should we have transformed? (its own R >= c)
    let mut correct = [0usize; 3];
    let mut total = 0usize;
    for (i, s) in full.samples.iter().enumerate() {
        let Some(r) = s.ratios else { continue };
        let truth = r.r >= cfg.c;
        // Rebuild the graph without matrix i (leave-one-out).
        let mut g = spmv_at::autotune::DrGraph::new();
        for (j, s2) in full.samples.iter().enumerate() {
            if j != i {
                if let Some(r2) = s2.ratios {
                    g.push(s2.name.clone(), s2.d_mat, r2.r);
                }
            }
        }
        let rules = [
            g.d_star(cfg.c),
            g.d_star_conservative(cfg.c),
            g.fit_power_law().map(|f| f.threshold(cfg.c)),
        ];
        for (k, d_star) in rules.iter().enumerate() {
            let predict = matches!(d_star, Some(d) if s.d_mat < *d);
            if predict == truth {
                correct[k] += 1;
            }
        }
        total += 1;
    }
    (
        correct[0] as f64 / total as f64,
        correct[1] as f64 / total as f64,
        correct[2] as f64 / total as f64,
    )
}

fn main() {
    common::banner("ablation", "design-choice ablations");
    let suite: Vec<(String, Csr)> = common::suite()
        .into_iter()
        .map(|(s, a)| (s.name.to_string(), a))
        .collect();
    let mut json = Vec::new();

    // --- 1. D* rule accuracy ---
    println!("\n[1] D* rule, leave-one-out decision accuracy:");
    let mut t = Table::new(vec!["machine", "paper-literal", "conservative", "power-law model"]);
    for (name, backend) in [
        ("ES2", Box::new(SimulatedBackend::new(VectorMachine::default())) as Box<dyn Backend>),
        ("SR16000", Box::new(SimulatedBackend::new(ScalarMachine::default()))),
    ] {
        let (lit, cons, model) = ablate_dstar(backend.as_ref(), &suite);
        t.row(vec![
            name.to_string(),
            format!("{:.0}%", lit * 100.0),
            format!("{:.0}%", cons * 100.0),
            format!("{:.0}%", model * 100.0),
        ]);
        json.push(Json::Obj(vec![
            ("ablation".into(), Json::Str("d_star_rule".into())),
            ("machine".into(), Json::Str(name.into())),
            ("literal".into(), Json::Num(lit)),
            ("conservative".into(), Json::Num(cons)),
            ("model".into(), Json::Num(model)),
        ]));
    }
    print!("{}", t.render());

    // --- 2. Partition policy imbalance ---
    println!("\n[2] row-partition imbalance at 8 threads (1.0 = perfect):");
    let mut t = Table::new(vec!["matrix", "D_mat", "split_even", "split_by_nnz"]);
    for (spec, a) in common::suite() {
        let even: Vec<_> = split_even(a.row_ptr.len() - 1, 8);
        let bynnz = split_by_nnz(&a.row_ptr, 8);
        let (ie, ib) = (imbalance(&a.row_ptr, &even), imbalance(&a.row_ptr, &bynnz));
        if spec.no % 4 == 1 || ie > 1.5 {
            t.row(vec![
                spec.name.to_string(),
                format!("{:.2}", spec.d_mat),
                format!("{ie:.2}"),
                format!("{ib:.2}"),
            ]);
        }
        json.push(Json::Obj(vec![
            ("ablation".into(), Json::Str("partition".into())),
            ("matrix".into(), Json::Str(spec.name.into())),
            ("even".into(), Json::Num(ie)),
            ("by_nnz".into(), Json::Num(ib)),
        ]));
    }
    print!("{}", t.render());

    // --- 3. BCSR vs ELL on the scalar model ---
    println!("\n[3] BCSR (future-work format) vs ELL, scalar model, 1 thread:");
    let sr = SimulatedBackend::new(ScalarMachine::default());
    let mut t = Table::new(vec!["matrix", "D_mat", "SP ell", "SP bcsr", "winner"]);
    for (spec, a) in common::suite() {
        let t_crs = sr.spmv_seconds(&a, Implementation::CsrSeq, 1).unwrap();
        let sp_ell = t_crs / sr.spmv_seconds(&a, Implementation::EllRowInner, 1).unwrap();
        let sp_bcsr = t_crs / sr.spmv_seconds(&a, Implementation::BcsrSeq, 1).unwrap();
        if spec.no % 3 == 0 || spec.no == 2 || spec.no == 6 {
            t.row(vec![
                spec.name.to_string(),
                format!("{:.2}", spec.d_mat),
                format!("{sp_ell:.2}"),
                format!("{sp_bcsr:.2}"),
                if sp_ell >= sp_bcsr { "ELL".into() } else { "BCSR".to_string() },
            ]);
        }
        json.push(Json::Obj(vec![
            ("ablation".into(), Json::Str("bcsr_vs_ell".into())),
            ("matrix".into(), Json::Str(spec.name.into())),
            ("sp_ell".into(), Json::Num(sp_ell)),
            ("sp_bcsr".into(), Json::Num(sp_bcsr)),
        ]));
    }
    print!("{}", t.render());

    // --- 4. Parallel transformation (paper future work), host-measured ---
    println!("\n[4] parallel CRS->ELL / CRS->CCS on host (speedup vs sequential):");
    let spec = spmv_at::matrixgen::spec_by_name("xenon1").unwrap();
    let sc = if common::quick() { 0.05 } else { 0.2 };
    let a = spmv_at::matrixgen::generate(&spec, common::seed(), sc);
    let r = common::reps(5);
    let t_ell_seq = time_median(1, r, || {
        std::hint::black_box(transform::crs_to_ell(&a).ok());
    });
    let t_ccs_seq = time_median(1, r, || {
        std::hint::black_box(transform::crs_to_ccs(&a));
    });
    let mut t = Table::new(vec!["threads", "ELL speedup", "CCS speedup"]);
    for threads in [1usize, 2, 4] {
        let t_ell = time_median(1, r, || {
            std::hint::black_box(transform::par::crs_to_ell_par(&a, threads).ok());
        });
        let t_ccs = time_median(1, r, || {
            std::hint::black_box(transform::par::crs_to_ccs_par(&a, threads));
        });
        t.row(vec![
            threads.to_string(),
            format!("{:.2}x", t_ell_seq / t_ell),
            format!("{:.2}x", t_ccs_seq / t_ccs),
        ]);
        json.push(Json::Obj(vec![
            ("ablation".into(), Json::Str("par_transform".into())),
            ("threads".into(), Json::Num(threads as f64)),
            ("ell_speedup".into(), Json::Num(t_ell_seq / t_ell)),
            ("ccs_speedup".into(), Json::Num(t_ccs_seq / t_ccs)),
        ]));
    }
    print!("{}", t.render());
    println!("(single-core host: parallel speedups ≈ overhead-only; the structure is what ships)");

    // --- 5. JDS / HYB extensions: fixing the ELL failure mode ---
    println!("\n[5] JDS & HYB (extensions) vs ELL on the vector model, 1 thread:");
    println!("    (the paper's ELL loses on high-D_mat matrices; fill-free JDS and");
    println!("     capped-bandwidth HYB are the classic fixes on this machine class)");
    let es2 = SimulatedBackend::new(VectorMachine::default());
    let mut t = Table::new(vec!["matrix", "D_mat", "SP ell", "SP jds", "SP hyb", "winner"]);
    for (spec, a) in common::suite() {
        let t_crs = es2.spmv_seconds(&a, Implementation::CsrSeq, 1).unwrap();
        let sp_ell = t_crs / es2.spmv_seconds(&a, Implementation::EllRowInner, 1).unwrap();
        let sp_jds = t_crs / es2.spmv_seconds(&a, Implementation::JdsSeq, 1).unwrap();
        let sp_hyb = t_crs / es2.spmv_seconds(&a, Implementation::HybSeq, 1).unwrap();
        if [2u32, 3, 6, 11, 17, 21].contains(&spec.no) {
            let win = [("ELL", sp_ell), ("JDS", sp_jds), ("HYB", sp_hyb)]
                .into_iter()
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap()
                .0;
            t.row(vec![
                spec.name.to_string(),
                format!("{:.2}", spec.d_mat),
                format!("{sp_ell:.1}"),
                format!("{sp_jds:.1}"),
                format!("{sp_hyb:.1}"),
                win.to_string(),
            ]);
        }
        json.push(Json::Obj(vec![
            ("ablation".into(), Json::Str("jds_hyb".into())),
            ("matrix".into(), Json::Str(spec.name.into())),
            ("sp_ell".into(), Json::Num(sp_ell)),
            ("sp_jds".into(), Json::Num(sp_jds)),
            ("sp_hyb".into(), Json::Num(sp_hyb)),
        ]));
    }
    print!("{}", t.render());

    common::write_json("ablation", Json::Arr(json));
}
