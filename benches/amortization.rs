//! §2.2 amortisation — the paper's cost argument quantified.
//!
//! "Our target is 2x–100x speedups to SpMV with CRS… Hence, the iteration
//! time based on the AT algorithm is approximately 2–100 times. This range
//! is achievable for many iterative solvers."
//!
//! Part A (models): for every Table-1 matrix × machine, compute the
//! break-even iteration count `TT / (1 − 1/SP)` from the modelled ratios
//! and check it lands in a solver-achievable range on the machine where
//! the AT says "transform".
//!
//! Part B (measured): on the host, run an actual `Durmv` handle and find
//! the empirical crossover — the iteration count where the AUTO path's
//! cumulative time (transformation included) drops below the plain-CRS
//! path.
//!
//! Part C (measured): `spmm_tile_sweep` — per-SpMV time of the tiled
//! `execute_many` SpMM at batch k ∈ {1, 4, 16, 64} against looped
//! single-RHS executes, making the single-pass-per-tile bandwidth win
//! measurable per PR.
//!
//! Part D (measured): `adaptive_replan` — the adaptive loop's two costs
//! per PR: decision-flip latency (calls + wall time from a contradicting
//! measurement, anchored on MeasuredBackend timings, to the serving-plan
//! swap) and exploration overhead (adaptive + forced shadow calls vs the
//! decide-once pipeline on the same traffic).
//!
//! Part E (measured): `numa_split` — cross-socket SpMM: per-RHS time of
//! `execute_split_many` (row blocks on socket-pinned shard pools, merged)
//! vs the unsplit `execute_many` on one pool, on a large synthetic
//! matrix. On a single-socket CI box this mostly prices the split's merge
//! overhead; on a multi-socket machine it tracks the locality win. The
//! split/unsplit checksums are asserted equal, so the case also guards
//! the bitwise property per PR.

#[path = "common.rs"]
mod common;

use spmv_at::autotune::atlib::{switches, Durmv};
use spmv_at::autotune::online::TuningData;
use spmv_at::autotune::{MemoryPolicy, Ratios};
use spmv_at::machine::scalar::ScalarMachine;
use spmv_at::machine::vector::VectorMachine;
use spmv_at::machine::{Backend, SimulatedBackend};
use spmv_at::metrics::{Json, Table};
use spmv_at::spmv::Implementation;

fn main() {
    common::banner("amortization", "break-even iteration counts (§2.2)");
    let suite = common::suite();
    let mut json = Vec::new();

    // ---- Part A: modelled break-even per machine ----
    for (mname, backend) in [
        ("ES2", Box::new(SimulatedBackend::new(VectorMachine::default())) as Box<dyn Backend>),
        ("SR16000", Box::new(SimulatedBackend::new(ScalarMachine::default()))),
    ] {
        println!("\n--- {mname}: modelled break-even (ELL-Row outer, 1 thread) ---");
        let mut t = Table::new(vec!["matrix", "D_mat", "SP", "TT", "R", "break-even iters"]);
        let mut in_range = 0usize;
        let mut transformable = 0usize;
        for (spec, a) in &suite {
            if spec.no == 3 {
                continue; // torso1: ELL excluded
            }
            let t_crs = backend.spmv_seconds(a, Implementation::CsrSeq, 1).unwrap();
            let t_imp = backend
                .spmv_seconds(a, Implementation::EllRowOuter, 1)
                .unwrap();
            let t_tr = backend
                .transform_seconds(a, Implementation::EllRowOuter)
                .unwrap();
            let r = Ratios::from_times(t_crs, t_imp, t_tr);
            let be = r.break_even_iterations();
            if r.r >= 1.0 {
                transformable += 1;
                // The paper's "2-100 iterations" achievability claim.
                if be <= 150.0 {
                    in_range += 1;
                }
            }
            if spec.no % 3 == 0 || spec.no == 2 || spec.no == 6 {
                t.row(vec![
                    spec.name.to_string(),
                    format!("{:.2}", spec.d_mat),
                    format!("{:.1}", r.sp),
                    format!("{:.2}", r.tt),
                    format!("{:.2}", r.r),
                    if be.is_finite() { format!("{be:.1}") } else { "never".into() },
                ]);
            }
            json.push(Json::Obj(vec![
                ("machine".into(), Json::Str(mname.into())),
                ("matrix".into(), Json::Str(spec.name.into())),
                ("sp".into(), Json::Num(r.sp)),
                ("tt".into(), Json::Num(r.tt)),
                ("break_even".into(), Json::Num(be)),
            ]));
        }
        print!("{}", t.render());
        println!(
            "matrices with R >= 1 whose break-even <= 150 iterations: {in_range}/{transformable} \
             (paper: 'approximately 2-100 times … achievable for many iterative solvers')"
        );
    }

    // ---- Part B: measured crossover on the host ----
    println!("\n--- host: measured crossover (AUTO vs CRS cumulative time) ---");
    let tuning = TuningData {
        backend: "sim:ES2".into(),
        imp: Implementation::EllRowOuter,
        threads: 1,
        c: 1.0,
        d_star: Some(3.1),
    };
    let mut t = Table::new(vec!["matrix", "D_mat", "crossover iters", "t_trans (ms)"]);
    for (spec, a) in suite.iter().filter(|(s, _)| [2u32, 12, 14].contains(&s.no)) {
        use spmv_at::formats::SparseMatrix as _;
        let n = a.n_rows();
        let x = vec![1.0; a.n_cols()];
        let mut y = vec![0.0; n];
        // CRS-only handle.
        let mut crs = Durmv::new(a.clone(), tuning.clone(), MemoryPolicy::unlimited(), 1);
        // AUTO handle (will transform on first call).
        let mut auto = Durmv::new(a.clone(), tuning.clone(), MemoryPolicy::unlimited(), 1);
        let mut t_crs_total = 0.0f64;
        let mut t_auto_total = 0.0f64;
        let mut crossover: Option<usize> = None;
        let max_iters = if common::quick() { 50 } else { 400 };
        for iter in 1..=max_iters {
            let t0 = std::time::Instant::now();
            crs.durmv(switches::CRS, &x, &mut y).unwrap();
            t_crs_total += t0.elapsed().as_secs_f64();
            let t0 = std::time::Instant::now();
            auto.durmv(switches::AUTO, &x, &mut y).unwrap();
            t_auto_total += t0.elapsed().as_secs_f64();
            if crossover.is_none() && t_auto_total < t_crs_total {
                crossover = Some(iter);
            }
        }
        t.row(vec![
            spec.name.to_string(),
            format!("{:.2}", spec.d_mat),
            crossover.map_or(format!(">{max_iters}"), |c| c.to_string()),
            format!("{:.3}", auto.transform_seconds * 1e3),
        ]);
        json.push(Json::Obj(vec![
            ("machine".into(), Json::Str("host".into())),
            ("matrix".into(), Json::Str(spec.name.into())),
            (
                "crossover".into(),
                crossover.map_or(Json::Null, |c| Json::Num(c as f64)),
            ),
            ("t_trans".into(), Json::Num(auto.transform_seconds)),
        ]));
    }
    print!("{}", t.render());
    println!("(AUTO includes the one-off transformation; crossover = amortisation point)");

    // ---- Part C: tiled SpMM sweep on the host ----
    println!("\n--- host: spmm_tile_sweep (tiled execute_many vs looped execute) ---");
    let backend = spmv_at::machine::MeasuredBackend::new(
        if common::quick() { 0 } else { 1 },
        common::reps(5),
    );
    let threads = spmv_at::spmv::pool::configured_threads().clamp(1, 8);
    let batches: &[usize] = if common::quick() { &[1, 4] } else { &[1, 4, 16, 64] };
    let mut t = Table::new(vec![
        "matrix",
        "imp",
        "batch k",
        "looped us/spmv",
        "tiled us/spmv",
        "speedup",
    ]);
    for (spec, a) in suite.iter().filter(|(s, _)| [2u32, 12].contains(&s.no)) {
        for imp in [Implementation::CsrRowPar, Implementation::EllRowInner] {
            let t_single = match backend.spmv_seconds(a, imp, threads) {
                Ok(t) => t,
                Err(_) => continue, // e.g. ELL excluded by shape
            };
            for &k in batches {
                let t_tiled = match backend.spmm_seconds_per_rhs(a, imp, threads, k, None) {
                    Ok(t) => t,
                    Err(_) => continue,
                };
                t.row(vec![
                    spec.name.to_string(),
                    imp.to_string(),
                    k.to_string(),
                    format!("{:.2}", t_single * 1e6),
                    format!("{:.2}", t_tiled * 1e6),
                    format!("{:.2}x", t_single / t_tiled.max(1e-12)),
                ]);
                json.push(Json::Obj(vec![
                    ("machine".into(), Json::Str("host".into())),
                    ("case".into(), Json::Str("spmm_tile_sweep".into())),
                    ("matrix".into(), Json::Str(spec.name.into())),
                    ("imp".into(), Json::Str(imp.name().into())),
                    ("batch".into(), Json::Num(k as f64)),
                    ("threads".into(), Json::Num(threads as f64)),
                    ("looped_seconds_per_spmv".into(), Json::Num(t_single)),
                    ("tiled_seconds_per_spmv".into(), Json::Num(t_tiled)),
                ]));
            }
        }
    }
    print!("{}", t.render());
    println!("(tiled = one matrix pass per SPMV_AT_BATCH_TILE column tile)");

    // ---- Part D: adaptive re-plan latency + exploration overhead ----
    println!("\n--- host: adaptive_replan (flip latency + exploration overhead) ---");
    {
        use spmv_at::coordinator::{Coordinator, CoordinatorConfig};
        use spmv_at::formats::{FormatKind, SparseMatrix as _};
        let spec = spmv_at::matrixgen::spec_by_name("chem_master1").unwrap();
        let a = spmv_at::matrixgen::generate(&spec, common::seed(), common::scale());
        let n = a.n_rows();
        let x = vec![1.0; n];
        let candidate = Implementation::EllRowInner;

        // Flip latency: factory table says "keep CRS" (no D*); the rival
        // arm is seeded with a MeasuredBackend timing scaled to contradict
        // it decisively, and we count serves until the controller swaps.
        let t_imp_measured = backend.spmv_seconds(&a, candidate, threads).unwrap_or(1e-6);
        let wrong = TuningData { d_star: None, imp: candidate, ..tuning.clone() };
        let mut cfg = CoordinatorConfig::new(wrong);
        cfg.threads = threads;
        cfg.adaptive.enabled = true;
        cfg.adaptive.epsilon = 0.0; // injected measurements only
        let mut coord = Coordinator::new(cfg.clone());
        coord.register("m", a.clone()).unwrap();
        coord.inject_sample("m", candidate, t_imp_measured * 1e-6, 16).unwrap();
        let budget = cfg.adaptive.window * u64::from(cfg.adaptive.flip_windows) + 1;
        let t0 = std::time::Instant::now();
        let mut flip_calls = None;
        for call in 1..=budget {
            coord.spmv("m", &x).unwrap();
            if coord.serving_format("m") == Some(FormatKind::Ell) {
                flip_calls = Some(call);
                break;
            }
        }
        let flip_seconds = t0.elapsed().as_secs_f64();
        let replans = coord.stats()[0].replans;

        // Exploration overhead: identical traffic through the decide-once
        // pipeline vs the adaptive loop with forced exploration.
        let iters = if common::quick() { 32 } else { 512 };
        let run = |adaptive: bool| -> (f64, u64) {
            let mut c = cfg.clone();
            c.adaptive.enabled = adaptive;
            c.adaptive.epsilon = 1.0;
            c.adaptive.explore_warmup = 0;
            c.adaptive.budget_fraction = f64::INFINITY; // measure the raw cost
            let mut coord = Coordinator::new(c);
            coord.register("m", a.clone()).unwrap();
            let t0 = std::time::Instant::now();
            for _ in 0..iters {
                coord.spmv("m", &x).unwrap();
            }
            (t0.elapsed().as_secs_f64(), coord.stats()[0].explored)
        };
        let (t_plain, _) = run(false);
        let (t_adapt, explored) = run(true);
        let overhead = t_adapt / t_plain.max(1e-12) - 1.0;

        let mut t = Table::new(vec!["metric", "value"]);
        t.row(vec![
            "flip latency (calls)".into(),
            flip_calls.map_or(format!(">{budget}"), |c| c.to_string()),
        ]);
        t.row(vec!["flip latency (ms)".into(), format!("{:.3}", flip_seconds * 1e3)]);
        t.row(vec!["replans".into(), replans.to_string()]);
        t.row(vec![
            format!("exploration overhead ({iters} calls, eps=1)"),
            format!("{:+.1}%", overhead * 1e2),
        ]);
        t.row(vec!["shadow calls".into(), explored.to_string()]);
        print!("{}", t.render());
        json.push(Json::Obj(vec![
            ("machine".into(), Json::Str("host".into())),
            ("case".into(), Json::Str("adaptive_replan".into())),
            ("matrix".into(), Json::Str(spec.name.into())),
            (
                "flip_calls".into(),
                flip_calls.map_or(Json::Null, |c| Json::Num(c as f64)),
            ),
            ("flip_seconds".into(), Json::Num(flip_seconds)),
            ("replans".into(), Json::Num(replans as f64)),
            ("explore_overhead_ratio".into(), Json::Num(overhead)),
            ("explored".into(), Json::Num(explored as f64)),
            ("threads".into(), Json::Num(threads as f64)),
        ]));
    }
    // ---- Part E: cross-socket split SpMM (numa_split) ----
    println!("\n--- host: numa_split (execute_split_many vs execute_many) ---");
    {
        use spmv_at::coordinator::{PlanShards, ShardedPlanner};
        use spmv_at::formats::SparseMatrix as _;
        use spmv_at::machine::Topology;
        use std::sync::Arc;

        let topo = Topology::detect();
        // Exercise the cross-shard path even on single-socket machines.
        let shards = topo.n_sockets().max(2);
        let spec = spmv_at::matrixgen::spec_by_name("xenon1").unwrap();
        let a = Arc::new(spmv_at::matrixgen::generate(
            &spec,
            common::seed(),
            common::scale() * if common::quick() { 1.0 } else { 2.0 },
        ));
        let n = a.n_rows();
        let k = if common::quick() { 4 } else { 16 };
        let xs: Vec<Vec<f64>> = (0..k)
            .map(|j| (0..a.n_cols()).map(|i| 1.0 + ((i + j) % 7) as f64 * 0.125).collect())
            .collect();
        let mut ys = vec![vec![0.0; n]; k];
        let imp = Implementation::CsrRowPar;
        let sp = ShardedPlanner::new(
            tuning.clone(),
            MemoryPolicy::unlimited(),
            PlanShards::spread_on(shards, threads, &topo),
        );

        // Unsplit: the whole matrix on shard 0's pool.
        let mut full = sp.planner(0).plan_for(&a, imp).unwrap();
        full.execute_many(&xs, &mut ys).unwrap(); // prime workspace
        let t_unsplit = spmv_at::metrics::time_median(common::reps(1), common::reps(5), || {
            full.execute_many(&xs, &mut ys).expect("unsplit SpMM");
        }) / k as f64;
        let unsplit_sum: f64 = ys.iter().flatten().sum();

        // Split: one nnz-balanced row block per shard, merged.
        let mut split = sp.plan_split(&a, imp, shards).unwrap();
        sp.execute_split_many(&mut split, &xs, &mut ys).unwrap(); // prime
        let t_split = spmv_at::metrics::time_median(common::reps(1), common::reps(5), || {
            sp.execute_split_many(&mut split, &xs, &mut ys).expect("split SpMM");
        }) / k as f64;
        let split_sum: f64 = ys.iter().flatten().sum();
        assert_eq!(
            split_sum.to_bits(),
            unsplit_sum.to_bits(),
            "split SpMM must agree bitwise with the unsplit plan"
        );
        // Overlap factor: max row blocks simultaneously in flight over
        // the timed runs, relative to the block count — 1.0 means every
        // block of a call was in flight at once (the concurrent
        // cross-socket execution ISSUE 5 added); a sequential split
        // would report 1/parts.
        let overlap_blocks = split.max_concurrent_blocks();
        let overlap_factor = overlap_blocks as f64 / split.parts().max(1) as f64;
        assert!(
            overlap_blocks >= split.parts().min(2) as u64,
            "split blocks must be in flight concurrently"
        );

        let mut t = Table::new(vec!["metric", "value"]);
        t.row(vec!["sockets (detected)".into(), topo.n_sockets().to_string()]);
        t.row(vec!["shards / blocks".into(), format!("{shards} / {}", split.parts())]);
        t.row(vec!["unsplit us/spmv".into(), format!("{:.2}", t_unsplit * 1e6)]);
        t.row(vec!["split us/spmv".into(), format!("{:.2}", t_split * 1e6)]);
        t.row(vec![
            "split speedup".into(),
            format!("{:.2}x", t_unsplit / t_split.max(1e-12)),
        ]);
        t.row(vec![
            "overlap (max blocks in flight / blocks)".into(),
            format!("{overlap_blocks} / {} = {overlap_factor:.2}", split.parts()),
        ]);
        print!("{}", t.render());
        json.push(Json::Obj(vec![
            ("machine".into(), Json::Str("host".into())),
            ("case".into(), Json::Str("numa_split".into())),
            ("matrix".into(), Json::Str(spec.name.into())),
            ("sockets".into(), Json::Num(topo.n_sockets() as f64)),
            ("shards".into(), Json::Num(shards as f64)),
            ("threads".into(), Json::Num(threads as f64)),
            ("batch".into(), Json::Num(k as f64)),
            ("unsplit_seconds_per_spmv".into(), Json::Num(t_unsplit)),
            ("split_seconds_per_spmv".into(), Json::Num(t_split)),
            ("overlap_max_blocks".into(), Json::Num(overlap_blocks as f64)),
            ("overlap_factor".into(), Json::Num(overlap_factor)),
        ]));
    }

    common::write_json("amortization", Json::Arr(json));
}
