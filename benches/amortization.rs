//! §2.2 amortisation — the paper's cost argument quantified.
//!
//! "Our target is 2x–100x speedups to SpMV with CRS… Hence, the iteration
//! time based on the AT algorithm is approximately 2–100 times. This range
//! is achievable for many iterative solvers."
//!
//! Part A (models): for every Table-1 matrix × machine, compute the
//! break-even iteration count `TT / (1 − 1/SP)` from the modelled ratios
//! and check it lands in a solver-achievable range on the machine where
//! the AT says "transform".
//!
//! Part B (measured): on the host, run an actual `Durmv` handle and find
//! the empirical crossover — the iteration count where the AUTO path's
//! cumulative time (transformation included) drops below the plain-CRS
//! path.
//!
//! Part C (measured): `spmm_tile_sweep` — per-SpMV time of the tiled
//! `execute_many` SpMM at batch k ∈ {1, 4, 16, 64} against looped
//! single-RHS executes, making the single-pass-per-tile bandwidth win
//! measurable per PR.

#[path = "common.rs"]
mod common;

use spmv_at::autotune::atlib::{switches, Durmv};
use spmv_at::autotune::online::TuningData;
use spmv_at::autotune::{MemoryPolicy, Ratios};
use spmv_at::machine::scalar::ScalarMachine;
use spmv_at::machine::vector::VectorMachine;
use spmv_at::machine::{Backend, SimulatedBackend};
use spmv_at::metrics::{Json, Table};
use spmv_at::spmv::Implementation;

fn main() {
    common::banner("amortization", "break-even iteration counts (§2.2)");
    let suite = common::suite();
    let mut json = Vec::new();

    // ---- Part A: modelled break-even per machine ----
    for (mname, backend) in [
        ("ES2", Box::new(SimulatedBackend::new(VectorMachine::default())) as Box<dyn Backend>),
        ("SR16000", Box::new(SimulatedBackend::new(ScalarMachine::default()))),
    ] {
        println!("\n--- {mname}: modelled break-even (ELL-Row outer, 1 thread) ---");
        let mut t = Table::new(vec!["matrix", "D_mat", "SP", "TT", "R", "break-even iters"]);
        let mut in_range = 0usize;
        let mut transformable = 0usize;
        for (spec, a) in &suite {
            if spec.no == 3 {
                continue; // torso1: ELL excluded
            }
            let t_crs = backend.spmv_seconds(a, Implementation::CsrSeq, 1).unwrap();
            let t_imp = backend
                .spmv_seconds(a, Implementation::EllRowOuter, 1)
                .unwrap();
            let t_tr = backend
                .transform_seconds(a, Implementation::EllRowOuter)
                .unwrap();
            let r = Ratios::from_times(t_crs, t_imp, t_tr);
            let be = r.break_even_iterations();
            if r.r >= 1.0 {
                transformable += 1;
                // The paper's "2-100 iterations" achievability claim.
                if be <= 150.0 {
                    in_range += 1;
                }
            }
            if spec.no % 3 == 0 || spec.no == 2 || spec.no == 6 {
                t.row(vec![
                    spec.name.to_string(),
                    format!("{:.2}", spec.d_mat),
                    format!("{:.1}", r.sp),
                    format!("{:.2}", r.tt),
                    format!("{:.2}", r.r),
                    if be.is_finite() { format!("{be:.1}") } else { "never".into() },
                ]);
            }
            json.push(Json::Obj(vec![
                ("machine".into(), Json::Str(mname.into())),
                ("matrix".into(), Json::Str(spec.name.into())),
                ("sp".into(), Json::Num(r.sp)),
                ("tt".into(), Json::Num(r.tt)),
                ("break_even".into(), Json::Num(be)),
            ]));
        }
        print!("{}", t.render());
        println!(
            "matrices with R >= 1 whose break-even <= 150 iterations: {in_range}/{transformable} \
             (paper: 'approximately 2-100 times … achievable for many iterative solvers')"
        );
    }

    // ---- Part B: measured crossover on the host ----
    println!("\n--- host: measured crossover (AUTO vs CRS cumulative time) ---");
    let tuning = TuningData {
        backend: "sim:ES2".into(),
        imp: Implementation::EllRowOuter,
        threads: 1,
        c: 1.0,
        d_star: Some(3.1),
    };
    let mut t = Table::new(vec!["matrix", "D_mat", "crossover iters", "t_trans (ms)"]);
    for (spec, a) in suite.iter().filter(|(s, _)| [2u32, 12, 14].contains(&s.no)) {
        use spmv_at::formats::SparseMatrix as _;
        let n = a.n_rows();
        let x = vec![1.0; a.n_cols()];
        let mut y = vec![0.0; n];
        // CRS-only handle.
        let mut crs = Durmv::new(a.clone(), tuning.clone(), MemoryPolicy::unlimited(), 1);
        // AUTO handle (will transform on first call).
        let mut auto = Durmv::new(a.clone(), tuning.clone(), MemoryPolicy::unlimited(), 1);
        let mut t_crs_total = 0.0f64;
        let mut t_auto_total = 0.0f64;
        let mut crossover: Option<usize> = None;
        let max_iters = if common::quick() { 50 } else { 400 };
        for iter in 1..=max_iters {
            let t0 = std::time::Instant::now();
            crs.durmv(switches::CRS, &x, &mut y).unwrap();
            t_crs_total += t0.elapsed().as_secs_f64();
            let t0 = std::time::Instant::now();
            auto.durmv(switches::AUTO, &x, &mut y).unwrap();
            t_auto_total += t0.elapsed().as_secs_f64();
            if crossover.is_none() && t_auto_total < t_crs_total {
                crossover = Some(iter);
            }
        }
        t.row(vec![
            spec.name.to_string(),
            format!("{:.2}", spec.d_mat),
            crossover.map_or(format!(">{max_iters}"), |c| c.to_string()),
            format!("{:.3}", auto.transform_seconds * 1e3),
        ]);
        json.push(Json::Obj(vec![
            ("machine".into(), Json::Str("host".into())),
            ("matrix".into(), Json::Str(spec.name.into())),
            (
                "crossover".into(),
                crossover.map_or(Json::Null, |c| Json::Num(c as f64)),
            ),
            ("t_trans".into(), Json::Num(auto.transform_seconds)),
        ]));
    }
    print!("{}", t.render());
    println!("(AUTO includes the one-off transformation; crossover = amortisation point)");

    // ---- Part C: tiled SpMM sweep on the host ----
    println!("\n--- host: spmm_tile_sweep (tiled execute_many vs looped execute) ---");
    let backend = spmv_at::machine::MeasuredBackend::new(
        if common::quick() { 0 } else { 1 },
        common::reps(5),
    );
    let threads = spmv_at::spmv::pool::configured_threads().clamp(1, 8);
    let batches: &[usize] = if common::quick() { &[1, 4] } else { &[1, 4, 16, 64] };
    let mut t = Table::new(vec![
        "matrix",
        "imp",
        "batch k",
        "looped us/spmv",
        "tiled us/spmv",
        "speedup",
    ]);
    for (spec, a) in suite.iter().filter(|(s, _)| [2u32, 12].contains(&s.no)) {
        for imp in [Implementation::CsrRowPar, Implementation::EllRowInner] {
            let t_single = match backend.spmv_seconds(a, imp, threads) {
                Ok(t) => t,
                Err(_) => continue, // e.g. ELL excluded by shape
            };
            for &k in batches {
                let t_tiled = match backend.spmm_seconds_per_rhs(a, imp, threads, k, None) {
                    Ok(t) => t,
                    Err(_) => continue,
                };
                t.row(vec![
                    spec.name.to_string(),
                    imp.to_string(),
                    k.to_string(),
                    format!("{:.2}", t_single * 1e6),
                    format!("{:.2}", t_tiled * 1e6),
                    format!("{:.2}x", t_single / t_tiled.max(1e-12)),
                ]);
                json.push(Json::Obj(vec![
                    ("machine".into(), Json::Str("host".into())),
                    ("case".into(), Json::Str("spmm_tile_sweep".into())),
                    ("matrix".into(), Json::Str(spec.name.into())),
                    ("imp".into(), Json::Str(imp.name().into())),
                    ("batch".into(), Json::Num(k as f64)),
                    ("threads".into(), Json::Num(threads as f64)),
                    ("looped_seconds_per_spmv".into(), Json::Num(t_single)),
                    ("tiled_seconds_per_spmv".into(), Json::Num(t_tiled)),
                ]));
            }
        }
    }
    print!("{}", t.render());
    println!("(tiled = one matrix pass per SPMV_AT_BATCH_TILE column tile)");
    common::write_json("amortization", Json::Arr(json));
}
