//! Fig. 6 — `SP_crs/ell` on the Earth Simulator 2 stand-in, 1–8 threads.
//!
//! Expected shapes (paper §4.3): >100× speedups with ELL everywhere except
//! memplus (no. 6), where COO-Row wins at ~2.75×; ELL-Row outer becomes
//! the best as threads grow; headline 151× (chem_master1, ELL-Row inner).
//! torso1 (no. 3) is excluded from ELL — memory overflow — exactly as the
//! paper removed it.

#[path = "common.rs"]
mod common;

use spmv_at::autotune::MemoryPolicy;
use spmv_at::formats::FormatKind;
use spmv_at::machine::vector::VectorMachine;
use spmv_at::machine::{Backend, MatrixShape, SimulatedBackend};
use spmv_at::metrics::{Json, Table};
use spmv_at::spmv::Implementation;

const THREADS: [usize; 4] = [1, 2, 4, 8];
/// ELL memory budget: 2 GiB at full scale, shrunk with the suite scale so
/// torso1's padded ELL is excluded at every scale — the paper's §4.2
/// "overflow memory space" case.
fn ell_budget() -> usize {
    ((2u64 << 30) as f64 * common::scale()) as usize
}

fn main() {
    common::banner("Fig. 6", "SP_crs/imp on the Earth Simulator 2 vector model");
    let backend = SimulatedBackend::new(VectorMachine::default());
    let suite = common::suite();
    let mut json_rows = Vec::new();
    let mut best_overall: (f64, String, Implementation, usize) =
        (0.0, String::new(), Implementation::CsrSeq, 1);
    let policy = MemoryPolicy::with_budget(ell_budget());

    for &threads in &THREADS {
        println!("\n--- {threads} thread(s) ---");
        let mut t = Table::new(vec![
            "no", "matrix", "D_mat", "COO-Col", "COO-Row", "ELL-Inner", "ELL-Outer", "best",
        ]);
        for (spec, a) in &suite {
            let shape = MatrixShape::of(a);
            let ell_ok = policy.admits(&shape, FormatKind::Ell);
            let t_crs = backend
                .spmv_seconds(a, Implementation::CsrRowPar, threads)
                .unwrap();
            let mut cells = vec![
                spec.no.to_string(),
                spec.name.to_string(),
                format!("{:.2}", spec.d_mat),
            ];
            let mut best = (0.0f64, "CRS");
            for imp in Implementation::AT_CANDIDATES {
                let is_ell = imp.required_format() == FormatKind::Ell;
                if is_ell && !ell_ok {
                    cells.push("excl".to_string());
                    continue;
                }
                let sp = t_crs / backend.spmv_seconds(a, imp, threads).unwrap();
                cells.push(format!("{sp:.1}"));
                if sp > best.0 {
                    best = (sp, imp.name());
                }
                if sp > best_overall.0 {
                    best_overall = (sp, spec.name.to_string(), imp, threads);
                }
                json_rows.push(Json::Obj(vec![
                    ("matrix".into(), Json::Str(spec.name.into())),
                    ("threads".into(), Json::Num(threads as f64)),
                    ("imp".into(), Json::Str(imp.name().into())),
                    ("sp".into(), Json::Num(sp)),
                ]));
            }
            cells.push(best.1.to_string());
            t.row(cells);
        }
        print!("{}", t.render());
    }

    println!(
        "\nheadline: max SP = {:.1}x ({}, {}, {} thread(s)) — paper: 151x \
         (chem_master1, ELL-Row inner)",
        best_overall.0, best_overall.1, best_overall.2, best_overall.3
    );
    // Paper conclusion 1: >100x for ELL except memplus, where COO-Row wins.
    let mut over_100 = 0;
    let mut memplus_best = String::new();
    for (spec, a) in &suite {
        let shape = MatrixShape::of(a);
        if !policy.admits(&shape, FormatKind::Ell) {
            continue;
        }
        let t_crs = backend.spmv_seconds(a, Implementation::CsrSeq, 1).unwrap();
        let sp_ell = t_crs
            / backend
                .spmv_seconds(a, Implementation::EllRowInner, 1)
                .unwrap();
        if sp_ell > 100.0 {
            over_100 += 1;
        }
        if spec.no == 6 {
            let sp_coo = t_crs
                / backend
                    .spmv_seconds(a, Implementation::CooRowOuter, 1)
                    .unwrap();
            memplus_best = format!(
                "memplus: ELL {sp_ell:.2}x vs COO-Row {sp_coo:.2}x -> best = {}",
                if sp_coo > sp_ell { "COO-Row (paper: COO-Row, 2.75x)" } else { "ELL (paper disagrees!)" }
            );
        }
    }
    println!(">100x ELL wins at 1 thread: {over_100} matrices (paper: all but memplus/torso1)");
    println!("{memplus_best}");
    common::write_json("fig6_vector", Json::Arr(json_rows));
}
