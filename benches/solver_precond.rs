//! Preconditioner bench (ISSUE 8 satellite): Jacobi-PCG vs SymGS-PCG
//! iterations and time-to-solution, the level-schedule statistics behind
//! the serial-vs-parallel SpTRSV decision, and the measured per-apply
//! cost of both triangle-solve modes.
//!
//! Two SPD systems frame the decision space: a banded circulant (short
//! level chains of wide levels — SpTRSV's parallel-friendly case) and a
//! badly-scaled random SPD (the solver suite's conditioning stress,
//! where SymGS's coupling pays off over the diagonal alone).
//!
//! Env knobs: SPMV_AT_SCALE/SPMV_AT_SEED as usual; SPMV_AT_THREADS sets
//! the SpTRSV pool width (default 4 here).

#[path = "common.rs"]
mod common;

use spmv_at::autotune::adaptive::AdaptiveConfig;
use spmv_at::formats::{Csr, SparseMatrix};
use spmv_at::matrixgen::{banded_circulant, make_spd, random_csr};
use spmv_at::metrics::{time_median, Json, Table};
use spmv_at::precond::{sptrsv, Jacobi, LevelSchedule, SymGs, TrsvPar};
use spmv_at::precond::{Preconditioner, TrsvMode};
use spmv_at::rng::Rng;
use spmv_at::solver::{pcg_with, SolverOptions};
use spmv_at::spmv::ParPool;
use std::sync::Arc;
use std::time::Instant;

fn threads() -> usize {
    std::env::var("SPMV_AT_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4)
}

fn n_for(base: usize) -> usize {
    // common::scale() is a fraction of the paper-scale suites; solver
    // benches stay host-sized, so apply it against a fixed base.
    ((base as f64) * (common::scale() / 0.2)).max(60.0) as usize
}

/// Banded SPD system: wide, regular levels.
fn band_spd(n: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    make_spd(&banded_circulant(&mut rng, n, &[-2, -1, 0, 1, 2]))
}

/// Badly-scaled random SPD system: the solver tests' conditioning case.
fn badscale_spd(n: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let base = make_spd(&random_csr(&mut rng, n, n, 0.05));
    let mut t = base.to_triplets();
    for i in 0..n {
        t.push((i, i, 10f64.powi((i % 4) as i32 * 2)));
    }
    Csr::from_triplets(n, n, &t).unwrap()
}

fn rhs(n: usize) -> Vec<f64> {
    (0..n).map(|i| 1.0 + ((i * 7) % 13) as f64 * 0.0625).collect()
}

/// One PCG run; returns (iterations, converged, wall seconds).
fn run_pcg(a: &Csr, m: &mut dyn Preconditioner, b: &[f64]) -> (usize, bool, f64) {
    let opts = SolverOptions { tol: 1e-10, max_iters: 5000 };
    let mut a = a.clone();
    let mut x = vec![0.0; b.len()];
    let t0 = Instant::now();
    let stats = pcg_with(&mut a, m, b, &mut x, &opts).expect("pcg");
    (stats.iterations, stats.converged, t0.elapsed().as_secs_f64())
}

/// Median per-apply seconds of serial and level-scheduled SpTRSV
/// (forward + diagonal scale + backward — one full SymGS sweep each).
fn sptrsv_pair(a: &Csr, pool: &Arc<ParPool>, reps: usize) -> (f64, f64) {
    let cfg = AdaptiveConfig { enabled: false, ..AdaptiveConfig::default() };
    let b = rhs(a.n_rows());
    let mut z = vec![0.0; a.n_rows()];
    let mut serial = SymGs::build(a, pool.clone(), TrsvPar::Never, &cfg).expect("symgs");
    let t_serial = time_median(1, reps, || serial.apply(&b, &mut z));
    let mut par = SymGs::build(a, pool.clone(), TrsvPar::Always, &cfg).expect("symgs");
    let t_par = time_median(1, reps, || par.apply(&b, &mut z));
    assert_eq!(serial.mode(), TrsvMode::Serial);
    assert_eq!(par.mode(), TrsvMode::LevelPar);
    (t_serial, t_par)
}

fn main() {
    common::banner("solver_precond", "Jacobi-PCG vs SymGS-PCG + SpTRSV mode economics");
    let reps = common::reps(9);
    let t = threads();
    let pool = Arc::new(ParPool::new(t));
    let cfg = AdaptiveConfig { enabled: false, ..AdaptiveConfig::default() };

    let systems: Vec<(&str, Csr)> = vec![
        ("band", band_spd(n_for(2000), common::seed())),
        ("badscale", badscale_spd(n_for(800), common::seed() + 10)),
    ];

    let mut json = Vec::new();
    let mut table = Table::new(vec![
        "system", "n", "nnz", "jacobi iters", "symgs iters", "jacobi s", "symgs s", "levels",
        "avg width", "serial us", "levelpar us", "auto mode",
    ]);

    for (name, a) in &systems {
        let n = a.n_rows();
        let b = rhs(n);

        let mut jac = Jacobi::build(a).expect("jacobi");
        let (j_iters, j_conv, j_secs) = run_pcg(a, &mut jac, &b);

        let mut sym = SymGs::build(a, pool.clone(), TrsvPar::Auto, &cfg).expect("symgs");
        let mode = sym.mode();
        let lo = *sym.lower_stats();
        let up = *sym.upper_stats();
        let analysis = sym.analysis_seconds();
        let (s_iters, s_conv, s_secs) = run_pcg(a, &mut sym, &b);

        let (t_serial, t_par) = sptrsv_pair(a, &pool, reps);

        // The level analysis is also a standalone cost worth tracking.
        let tri = a.split_triangular().expect("split");
        let t_analysis = time_median(0, reps, || {
            std::hint::black_box(LevelSchedule::build_lower(&tri.lower, t));
        });

        table.row(vec![
            name.to_string(),
            n.to_string(),
            a.nnz().to_string(),
            format!("{j_iters}{}", if j_conv { "" } else { "!" }),
            format!("{s_iters}{}", if s_conv { "" } else { "!" }),
            format!("{j_secs:.4}"),
            format!("{s_secs:.4}"),
            lo.levels.to_string(),
            format!("{:.1}", lo.avg_width),
            format!("{:.2}", t_serial * 1e6),
            format!("{:.2}", t_par * 1e6),
            mode.name().to_string(),
        ]);
        json.push(Json::Obj(vec![
            ("system".into(), Json::Str((*name).into())),
            ("n".into(), Json::Num(n as f64)),
            ("nnz".into(), Json::Num(a.nnz() as f64)),
            ("threads".into(), Json::Num(t as f64)),
            ("jacobi_iters".into(), Json::Num(j_iters as f64)),
            ("jacobi_converged".into(), Json::Bool(j_conv)),
            ("jacobi_seconds".into(), Json::Num(j_secs)),
            ("symgs_iters".into(), Json::Num(s_iters as f64)),
            ("symgs_converged".into(), Json::Bool(s_conv)),
            ("symgs_seconds".into(), Json::Num(s_secs)),
            ("levels_lower".into(), Json::Num(lo.levels as f64)),
            ("avg_width_lower".into(), Json::Num(lo.avg_width)),
            ("max_width_lower".into(), Json::Num(lo.max_width as f64)),
            ("levels_upper".into(), Json::Num(up.levels as f64)),
            ("avg_width_upper".into(), Json::Num(up.avg_width)),
            ("max_width_upper".into(), Json::Num(up.max_width as f64)),
            ("analysis_seconds".into(), Json::Num(analysis)),
            ("level_build_seconds".into(), Json::Num(t_analysis)),
            ("sptrsv_serial_us".into(), Json::Num(t_serial * 1e6)),
            ("sptrsv_parallel_us".into(), Json::Num(t_par * 1e6)),
            ("auto_mode".into(), Json::Str(mode.name().into())),
        ]));
    }

    print!("{}", table.render());
    common::write_json("solver_precond", Json::Arr(json));
}
