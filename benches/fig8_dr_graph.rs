//! Fig. 8 — the `D_mat`–`R_ell` graph (ELL-Row outer, 1 thread) on both
//! machine stand-ins, with `D*` extraction and the §4.5 power-law model.
//!
//! Expected shapes (paper §4.4): on the ES2 every matrix from D=0.02 to
//! D=3.10 clears `R ≥ 1` (D* ≈ 3.10); on the SR16000 only matrices with
//! `D ≲ 0.1` do (D* ≈ 0.1).

#[path = "common.rs"]
mod common;

use spmv_at::autotune::{run_offline, OfflineConfig};
use spmv_at::formats::Csr;
use spmv_at::machine::scalar::ScalarMachine;
use spmv_at::machine::vector::VectorMachine;
use spmv_at::machine::{Backend, SimulatedBackend};
use spmv_at::metrics::Json;

fn run(name: &str, backend: &dyn Backend, suite: &[(String, Csr)]) -> Json {
    let cfg = OfflineConfig::default(); // ELL-Row outer, 1 thread, c = 1.0
    let result = run_offline(backend, suite, &cfg).expect("offline phase");
    println!("\n=== {name} ===");
    print!("{}", result.graph.render(cfg.c));
    println!(
        "conservative D* = {:?}",
        result.graph.d_star_conservative(cfg.c)
    );
    if let Some(fit) = result.graph.fit_power_law() {
        println!(
            "model: R ~= {:.3} * D^{:.3} (R2 = {:.3}); model threshold at c={} -> D = {:.3}",
            fit.a,
            fit.b,
            fit.r2,
            cfg.c,
            fit.threshold(cfg.c)
        );
    }
    let excluded: Vec<&str> = result
        .samples
        .iter()
        .filter(|s| s.ratios.is_none())
        .map(|s| s.name.as_str())
        .collect();
    if !excluded.is_empty() {
        println!("excluded (transformation failed): {excluded:?}");
    }
    result.to_json()
}

fn main() {
    common::banner("Fig. 8", "the D_mat–R_ell graph, ELL-Row outer, 1 thread");
    // torso1 (no. 3) is excluded: its ELL data was removed by the paper
    // for memory overflow (§4.2) and the memory policy rejects it here.
    let suite: Vec<(String, Csr)> = common::suite()
        .into_iter()
        .filter(|(s, _)| s.no != 3)
        .map(|(s, a)| (s.name.to_string(), a))
        .collect();
    println!("(torso1 excluded from the ELL characterisation — §4.2 memory overflow)");
    let es2 = SimulatedBackend::new(VectorMachine::default());
    let sr = SimulatedBackend::new(ScalarMachine::default());
    let j_es2 = run("ES2 (vector model)", &es2, &suite);
    let j_sr = run("SR16000 (scalar model)", &sr, &suite);
    println!(
        "\npaper shapes: ES2 accepts D in [0.02, 3.10]; SR16000 accepts only D < ~0.1."
    );
    common::write_json(
        "fig8_dr_graph",
        Json::Obj(vec![("es2".into(), j_es2), ("sr16000".into(), j_sr)]),
    );
}
