//! Fig. 5 — `SP_crs/ell` on the HITACHI SR16000/VL1 stand-in, 1–128
//! threads, all four candidate implementations over the 22-matrix suite.
//!
//! Expected shapes (paper §4.3): speedup mainly at 1 thread; ELL beats COO
//! at low thread counts (memplus excepted); no ELL advantage left at
//! 64–128 threads. Headline: ≤ 2.45× (chem_master1, ELL-Row inner, 1t).

#[path = "common.rs"]
mod common;

use spmv_at::machine::scalar::ScalarMachine;
use spmv_at::machine::{Backend, SimulatedBackend};
use spmv_at::metrics::{Json, Table};
use spmv_at::spmv::Implementation;

const THREADS: [usize; 5] = [1, 4, 16, 64, 128];

fn main() {
    common::banner("Fig. 5", "SP_crs/imp on the SR16000/VL1 scalar model");
    let backend = SimulatedBackend::new(ScalarMachine::default());
    let suite = common::suite();
    let mut json_rows = Vec::new();
    let mut best_overall: (f64, String, Implementation, usize) =
        (0.0, String::new(), Implementation::CsrSeq, 1);

    for &threads in &THREADS {
        println!("\n--- {threads} thread(s) ---");
        let mut t = Table::new(vec![
            "no", "matrix", "D_mat", "COO-Col", "COO-Row", "ELL-Inner", "ELL-Outer", "best",
        ]);
        for (spec, a) in &suite {
            let t_crs = backend
                .spmv_seconds(a, Implementation::CsrRowPar, threads)
                .unwrap();
            let mut cells = vec![
                spec.no.to_string(),
                spec.name.to_string(),
                format!("{:.2}", spec.d_mat),
            ];
            let mut best = (0.0f64, "CRS");
            for imp in Implementation::AT_CANDIDATES {
                let sp = t_crs / backend.spmv_seconds(a, imp, threads).unwrap();
                cells.push(format!("{sp:.2}"));
                if sp > best.0 {
                    best = (sp, imp.name());
                }
                if sp > best_overall.0 {
                    best_overall = (sp, spec.name.to_string(), imp, threads);
                }
                json_rows.push(Json::Obj(vec![
                    ("matrix".into(), Json::Str(spec.name.into())),
                    ("threads".into(), Json::Num(threads as f64)),
                    ("imp".into(), Json::Str(imp.name().into())),
                    ("sp".into(), Json::Num(sp)),
                ]));
            }
            cells.push(if best.0 >= 1.0 { best.1.to_string() } else { "CRS".into() });
            t.row(cells);
        }
        print!("{}", t.render());
    }

    println!(
        "\nheadline: max SP = {:.2}x ({}, {}, {} thread(s)) — paper: 2.45x \
         (chem_master1, ELL-Row inner, 1 thread)",
        best_overall.0,
        best_overall.1,
        best_overall.2,
        best_overall.3
    );
    // Paper conclusion 3: no ELL advantage at 64/128 threads.
    let mut hi_thread_wins = 0;
    for (spec, a) in &suite {
        for &threads in &[64usize, 128] {
            let t_crs = backend
                .spmv_seconds(a, Implementation::CsrRowPar, threads)
                .unwrap();
            for imp in [Implementation::EllRowInner, Implementation::EllRowOuter] {
                if t_crs / backend.spmv_seconds(a, imp, threads).unwrap() > 1.4 {
                    hi_thread_wins += 1;
                    println!("  note: {} still wins with {imp} at {threads}t", spec.name);
                }
            }
        }
    }
    println!(
        "ELL wins >1.4x at 64/128 threads: {hi_thread_wins} cases — paper: none"
    );
    common::write_json("fig5_scalar", Json::Arr(json_rows));
}
