//! Shared helpers for the bench binaries (the environment has no
//! criterion; each bench is a `harness = false` main that prints the
//! paper's table/figure and dumps machine-readable JSON under
//! `target/bench-results/`).

use spmv_at::formats::Csr;
use spmv_at::matrixgen::{generate, table1_specs, MatrixSpec};
use spmv_at::metrics::Json;

/// Suite scale factor: `SPMV_AT_SCALE` env var, default 0.2 (preserves
/// μ/σ/D_mat; see matrixgen::suite docs).
#[allow(dead_code)]
pub fn scale() -> f64 {
    std::env::var("SPMV_AT_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.2)
}

/// Deterministic suite seed (`SPMV_AT_SEED`, default 42).
#[allow(dead_code)]
pub fn seed() -> u64 {
    std::env::var("SPMV_AT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(42)
}

/// Generate the full Table-1 suite at the configured scale.
#[allow(dead_code)]
pub fn suite() -> Vec<(MatrixSpec, Csr)> {
    let (sc, sd) = (scale(), seed());
    table1_specs()
        .into_iter()
        .map(|spec| {
            let a = generate(&spec, sd, sc);
            (spec, a)
        })
        .collect()
}

/// Write a bench's JSON payload to `target/bench-results/<name>.json`.
#[allow(dead_code)]
pub fn write_json(name: &str, payload: Json) {
    let dir = std::path::Path::new("target/bench-results");
    std::fs::create_dir_all(dir).expect("create bench-results dir");
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, payload.render()).expect("write bench json");
    println!("\n[json -> {}]", path.display());
}

/// Standard bench banner.
#[allow(dead_code)]
pub fn banner(id: &str, what: &str) {
    println!("================================================================");
    println!("{id}: {what}");
    println!("scale={} seed={}", scale(), seed());
    println!("================================================================");
}
