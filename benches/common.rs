//! Shared helpers for the bench binaries (the environment has no
//! criterion; each bench is a `harness = false` main that prints the
//! paper's table/figure and dumps machine-readable JSON under
//! `target/bench-results/`).
//!
//! **Quick mode** (`--quick` argv flag or `SPMV_AT_QUICK=1`): the CI
//! bench-smoke job runs every bench in a 1-iteration / reduced-scale mode
//! so each binary exercises its full code path in seconds. Every JSON
//! write also rebuilds the combined `target/bench-results/BENCH_pr.json`
//! (one key per bench), which CI uploads as the per-PR perf-trajectory
//! artifact.

use spmv_at::formats::Csr;
use spmv_at::matrixgen::{generate, table1_specs, MatrixSpec};
use spmv_at::metrics::Json;

/// Whether the bench runs in quick (smoke) mode: `--quick` on the
/// command line or `SPMV_AT_QUICK=1` in the environment.
#[allow(dead_code)]
pub fn quick() -> bool {
    std::env::args().any(|a| a == "--quick")
        || std::env::var("SPMV_AT_QUICK").map(|v| v.trim() == "1").unwrap_or(false)
}

/// Clamp a repetition/iteration count to 1 in quick mode.
#[allow(dead_code)]
pub fn reps(full: usize) -> usize {
    if quick() {
        1
    } else {
        full
    }
}

/// Suite scale factor: `SPMV_AT_SCALE` env var, default 0.2 (0.05 in
/// quick mode; preserves μ/σ/D_mat — see matrixgen::suite docs).
#[allow(dead_code)]
pub fn scale() -> f64 {
    std::env::var("SPMV_AT_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if quick() { 0.05 } else { 0.2 })
}

/// Deterministic suite seed (`SPMV_AT_SEED`, default 42).
#[allow(dead_code)]
pub fn seed() -> u64 {
    std::env::var("SPMV_AT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(42)
}

/// Generate the full Table-1 suite at the configured scale.
#[allow(dead_code)]
pub fn suite() -> Vec<(MatrixSpec, Csr)> {
    let (sc, sd) = (scale(), seed());
    table1_specs()
        .into_iter()
        .map(|spec| {
            let a = generate(&spec, sd, sc);
            (spec, a)
        })
        .collect()
}

/// Write a bench's JSON payload to `target/bench-results/<name>.json`
/// and refresh the combined `BENCH_pr.json` (one key per bench file) the
/// CI bench-smoke job uploads.
#[allow(dead_code)]
pub fn write_json(name: &str, payload: Json) {
    let dir = std::path::Path::new("target/bench-results");
    std::fs::create_dir_all(dir).expect("create bench-results dir");
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, payload.render()).expect("write bench json");
    println!("\n[json -> {}]", path.display());
    rebuild_combined(dir);
}

/// Rebuild `BENCH_pr.json` by stitching every per-bench JSON file in
/// `dir` into one object `{"<bench>": <payload>, ...}` (the payloads are
/// already rendered JSON, so plain concatenation stays valid).
#[allow(dead_code)]
fn rebuild_combined(dir: &std::path::Path) {
    let mut names: Vec<String> = match std::fs::read_dir(dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let f = e.file_name().to_string_lossy().into_owned();
                f.strip_suffix(".json")
                    .filter(|stem| *stem != "BENCH_pr")
                    .map(str::to_string)
            })
            .collect(),
        Err(_) => return,
    };
    names.sort();
    let mut out = String::from("{\n");
    let mut first = true;
    for name in names {
        let Ok(body) = std::fs::read_to_string(dir.join(format!("{name}.json"))) else {
            continue;
        };
        if !first {
            out.push_str(",\n");
        }
        first = false;
        out.push_str(&format!("\"{name}\": {}", body.trim_end()));
    }
    out.push_str("\n}\n");
    let combined = dir.join("BENCH_pr.json");
    std::fs::write(&combined, out).expect("write combined bench json");
    println!("[combined -> {}]", combined.display());
}

/// Standard bench banner.
#[allow(dead_code)]
pub fn banner(id: &str, what: &str) {
    println!("================================================================");
    println!("{id}: {what}");
    println!(
        "scale={} seed={}{}",
        scale(),
        seed(),
        if quick() { " (quick mode)" } else { "" }
    );
    println!("================================================================");
}
