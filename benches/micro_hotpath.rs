//! Hot-path microbenchmarks on the host CPU: real wall-clock for the
//! transformations (§2.1), every SpMV kernel (§3) executed through a
//! cached `SpmvPlan`, and the per-call dispatch overhead of the
//! persistent pool vs. spawn-per-call scoped threads. This is the
//! measurement substrate for the performance pass (EXPERIMENTS.md §Perf):
//! run before/after every optimisation.
//!
//! Env knobs: SPMV_AT_SCALE (default 0.05 here — host wallclock, keep it
//! quick), SPMV_AT_REPS (default 7), SPMV_AT_THREADS (pool width for the
//! dispatch-overhead case).

#[path = "common.rs"]
mod common;

use spmv_at::formats::{Csr, SparseMatrix};
use spmv_at::matrixgen::rowlen::stats_of_row_ptr;
use spmv_at::matrixgen::{generate, spec_by_name};
use spmv_at::metrics::{time_median, Json, Table};
use spmv_at::spmv::partition::{split_even, PartitionStrategy};
use spmv_at::spmv::pool::{configured_threads, ParPool};
use spmv_at::spmv::{Implementation, SpmvPlan};
use spmv_at::transform;
use std::sync::Arc;

fn reps() -> usize {
    std::env::var("SPMV_AT_REPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if common::quick() { 1 } else { 7 })
}

fn scale() -> f64 {
    std::env::var("SPMV_AT_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if common::quick() { 0.02 } else { 0.05 })
}

/// Representative matrices: near-band (best ELL case), moderate, heavy
/// tail (worst ELL case), big-μ structural.
const PICKS: [&str; 4] = ["chem_master1", "xenon1", "memplus", "sme3Da"];

fn bench_transforms(a: &Csr, name: &str, json: &mut Vec<Json>) -> Vec<String> {
    let r = reps();
    let t_coo_row = time_median(1, r, || {
        std::hint::black_box(transform::crs_to_coo_row(a));
    });
    let t_ccs = time_median(1, r, || {
        std::hint::black_box(transform::crs_to_ccs(a));
    });
    let t_coo_col = time_median(1, r, || {
        std::hint::black_box(transform::crs_to_coo_col(a));
    });
    let t_ell = time_median(1, r, || {
        std::hint::black_box(transform::crs_to_ell(a).ok());
    });
    let t_bcsr = time_median(1, r, || {
        std::hint::black_box(transform::crs_to_bcsr(a, 2, 2).ok());
    });
    let t_sell = time_median(1, r, || {
        std::hint::black_box(transform::crs_to_sell(a).ok());
    });
    json.push(Json::Obj(vec![
        ("matrix".into(), Json::Str(name.into())),
        ("kind".into(), Json::Str("transform".into())),
        ("coo_row".into(), Json::Num(t_coo_row)),
        ("ccs".into(), Json::Num(t_ccs)),
        ("coo_col".into(), Json::Num(t_coo_col)),
        ("ell".into(), Json::Num(t_ell)),
        ("bcsr".into(), Json::Num(t_bcsr)),
        ("sell".into(), Json::Num(t_sell)),
    ]));
    vec![
        format!("{:.3}", t_coo_row * 1e3),
        format!("{:.3}", t_ccs * 1e3),
        format!("{:.3}", t_coo_col * 1e3),
        format!("{:.3}", t_ell * 1e3),
        format!("{:.3}", t_bcsr * 1e3),
        format!("{:.3}", t_sell * 1e3),
    ]
}

fn bench_kernels(
    a: &Arc<Csr>,
    name: &str,
    pool: &Arc<ParPool>,
    json: &mut Vec<Json>,
) -> Vec<String> {
    let r = reps();
    let x: Vec<f64> = (0..a.n_cols()).map(|i| 1.0 + (i % 9) as f64 * 0.1).collect();
    let mut y = vec![0.0; a.n_rows()];
    let mut cells = Vec::new();
    let gflops = |t: f64| 2.0 * a.nnz() as f64 / t / 1e9;
    for imp in Implementation::ALL {
        let mut plan = match SpmvPlan::build(a, imp, None, pool.clone()) {
            Ok(p) => p,
            Err(_) => {
                cells.push("-".to_string());
                continue;
            }
        };
        plan.execute(&x, &mut y).unwrap();
        let t = time_median(1, r, || {
            plan.execute(&x, &mut y).unwrap();
        });
        std::hint::black_box(&y);
        cells.push(format!("{:.3}/{:.2}", t * 1e3, gflops(t)));
        json.push(Json::Obj(vec![
            ("matrix".into(), Json::Str(name.into())),
            ("kind".into(), Json::Str("spmv".into())),
            ("imp".into(), Json::Str(imp.name().into())),
            ("seconds".into(), Json::Num(t)),
            ("gflops".into(), Json::Num(gflops(t))),
        ]));
    }
    cells
}

/// Achieved flops/byte per kernel: flops = 2·nnz, bytes = resident
/// format bytes + one read of `x` + one write of `y`. The padding a
/// format carries dilutes its arithmetic intensity, so the SELL-over-ELL
/// uplift here is exactly the padding the σ-window sort removed — the
/// quantity the D_mat–R model prices. Run on a band suite (near-uniform
/// rows, ELL's best case) and a random suite (spread row lengths, where
/// ELL pads heavily), with the measured per-call seconds alongside.
fn bench_flops_per_byte(pool: &Arc<ParPool>, json: &mut Vec<Json>) {
    let r = reps();
    let suites: [(&str, [&str; 2]); 2] =
        [("band", ["chem_master1", "xenon1"]), ("random", ["memplus", "sme3Da"])];
    println!("\nachieved flops/byte (2*nnz / (format bytes + x + y)), pool size 1:");
    let mut t = Table::new(vec![
        "suite", "matrix", "ELL-In f/B", "SELL f/B", "uplift", "ELL-In ms", "SELL ms",
    ]);
    for (suite, names) in suites {
        for name in names {
            let spec = spec_by_name(name).unwrap();
            let a = Arc::new(generate(&spec, common::seed(), scale()));
            let flops = 2.0 * a.nnz() as f64;
            let vec_bytes =
                ((a.n_cols() + a.n_rows()) * std::mem::size_of::<f64>()) as f64;
            let x: Vec<f64> = (0..a.n_cols()).map(|i| 1.0 + (i % 9) as f64 * 0.1).collect();
            let mut y = vec![0.0; a.n_rows()];
            let mut fpb = [f64::NAN; 2];
            let mut ms = [f64::NAN; 2];
            let imps = [Implementation::EllRowInner, Implementation::SellRowInner];
            for (k, imp) in imps.into_iter().enumerate() {
                let bytes = match imp {
                    Implementation::SellRowInner => {
                        transform::crs_to_sell(&a).map(|m| m.memory_bytes())
                    }
                    _ => transform::crs_to_ell(&a).map(|m| m.memory_bytes()),
                };
                let Ok(bytes) = bytes else { continue };
                let mut plan = SpmvPlan::build(&a, imp, None, pool.clone()).unwrap();
                plan.execute(&x, &mut y).unwrap();
                let secs = time_median(1, r, || {
                    plan.execute(&x, &mut y).unwrap();
                });
                std::hint::black_box(&y);
                fpb[k] = flops / (bytes as f64 + vec_bytes);
                ms[k] = secs * 1e3;
                json.push(Json::Obj(vec![
                    ("kind".into(), Json::Str("flops_per_byte".into())),
                    ("suite".into(), Json::Str(suite.into())),
                    ("matrix".into(), Json::Str(name.into())),
                    ("imp".into(), Json::Str(imp.name().into())),
                    ("flops_per_byte".into(), Json::Num(fpb[k])),
                    ("format_bytes".into(), Json::Num(bytes as f64)),
                    ("seconds".into(), Json::Num(secs)),
                ]));
            }
            t.row(vec![
                suite.to_string(),
                name.to_string(),
                format!("{:.4}", fpb[0]),
                format!("{:.4}", fpb[1]),
                format!("{:.2}x", fpb[1] / fpb[0]),
                format!("{:.3}", ms[0]),
                format!("{:.3}", ms[1]),
            ]);
        }
    }
    print!("{}", t.render());
}

/// Merge-path CRS vs conventional row-parallel CRS on a real pool — the
/// number the adaptive `CsrRowPar ↔ CsrMergePar` flip arbitrates. Run on
/// the tail-heavy picks (merge-path's target shape) plus one near-band
/// contrast case where row-aligned splits are already balanced, with the
/// row-length skew (max/mean) alongside so the table reads directly
/// against the planner's 8x pick threshold.
fn bench_merge_vs_rowpar(json: &mut Vec<Json>) {
    let r = reps();
    let threads = configured_threads().clamp(2, 8);
    let pool = Arc::new(ParPool::new(threads));
    println!("\nmerge-path vs row-parallel CRS ({threads} threads, ms):");
    let mut t = Table::new(vec!["matrix", "skew", "CRS-Par", "CRS-Merge", "merge speedup"]);
    for name in ["chem_master1", "memplus", "sme3Da"] {
        let spec = spec_by_name(name).unwrap();
        let a = Arc::new(generate(&spec, common::seed(), scale()));
        let st = stats_of_row_ptr(&a.row_ptr);
        let skew = st.max as f64 / st.mean.max(1e-12);
        let x: Vec<f64> = (0..a.n_cols()).map(|i| 1.0 + (i % 9) as f64 * 0.1).collect();
        let mut y = vec![0.0; a.n_rows()];
        let mut secs = [f64::NAN; 2];
        let cases = [
            (Implementation::CsrRowPar, Some(PartitionStrategy::ByNnz)),
            (Implementation::CsrMergePar, None),
        ];
        for (k, (imp, strategy)) in cases.into_iter().enumerate() {
            let mut plan =
                SpmvPlan::build_with(&a, imp, None, pool.clone(), strategy).unwrap();
            plan.execute(&x, &mut y).unwrap();
            secs[k] = time_median(1, r, || {
                plan.execute(&x, &mut y).unwrap();
            });
            std::hint::black_box(&y);
        }
        t.row(vec![
            name.to_string(),
            format!("{skew:.1}x"),
            format!("{:.3}", secs[0] * 1e3),
            format!("{:.3}", secs[1] * 1e3),
            format!("{:.2}x", secs[0] / secs[1].max(1e-12)),
        ]);
        json.push(Json::Obj(vec![
            ("kind".into(), Json::Str("merge_vs_rowpar".into())),
            ("matrix".into(), Json::Str(name.into())),
            ("threads".into(), Json::Num(threads as f64)),
            ("skew".into(), Json::Num(skew)),
            ("rowpar_seconds".into(), Json::Num(secs[0])),
            ("merge_seconds".into(), Json::Num(secs[1])),
            ("speedup".into(), Json::Num(secs[0] / secs[1].max(1e-12))),
        ]));
    }
    print!("{}", t.render());
}

/// The tentpole's headline number: per-call dispatch cost of the
/// persistent pool vs. a fresh `std::thread::scope` fork/join, on a
/// trivially cheap body (sum a range of `x`) so dispatch dominates at
/// small `n` and amortises at large `n`.
fn bench_pool_vs_scoped(json: &mut Vec<Json>) {
    let r = if common::quick() { 3 } else { reps().max(9) };
    let threads = configured_threads().clamp(2, 8);
    let pool = ParPool::new(threads);
    println!(
        "\ndispatch overhead ({threads} threads): spawn-per-call vs persistent pool (us/call):"
    );
    let mut t = Table::new(vec!["n", "scoped", "pool", "speedup"]);
    for n in [1_000usize, 100_000] {
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.001).sin()).collect();
        let ranges = split_even(n, threads);
        let body = |rr: std::ops::Range<usize>| {
            let mut acc = 0.0;
            for i in rr {
                acc += x[i];
            }
            std::hint::black_box(acc);
        };
        let t_scoped = time_median(2, r, || {
            std::thread::scope(|s| {
                for rr in &ranges {
                    let rr = rr.clone();
                    s.spawn(|| body(rr));
                }
            });
        });
        let t_pool = time_median(2, r, || {
            pool.run_chunks(&ranges, |_tid, rr| body(rr));
        });
        t.row(vec![
            n.to_string(),
            format!("{:.2}", t_scoped * 1e6),
            format!("{:.2}", t_pool * 1e6),
            format!("{:.2}x", t_scoped / t_pool.max(1e-12)),
        ]);
        json.push(Json::Obj(vec![
            ("kind".into(), Json::Str("pool_vs_scoped".into())),
            ("n".into(), Json::Num(n as f64)),
            ("threads".into(), Json::Num(threads as f64)),
            ("scoped_seconds".into(), Json::Num(t_scoped)),
            ("pool_seconds".into(), Json::Num(t_pool)),
        ]));
    }
    print!("{}", t.render());
}

fn main() {
    common::banner("micro_hotpath", "host wallclock: transforms + SpMV plans + dispatch overhead");
    let mut json = Vec::new();

    println!("\ntransformations (ms):");
    let mut tt =
        Table::new(vec!["matrix", "n", "nnz", "COO-Row", "CCS", "COO-Col", "ELL", "BCSR", "SELL"]);
    for name in PICKS {
        let spec = spec_by_name(name).unwrap();
        let a = generate(&spec, common::seed(), scale());
        let mut row = vec![name.to_string(), a.n_rows().to_string(), a.nnz().to_string()];
        row.extend(bench_transforms(&a, name, &mut json));
        tt.row(row);
    }
    print!("{}", tt.render());

    println!("\nSpMV plans (ms / GFLOP-s), pool size 1:");
    let pool1 = Arc::new(ParPool::new(1));
    let mut kt = Table::new(vec![
        "matrix", "CRS", "CRS-Par", "COO-Col", "COO-Row", "ELL-In", "ELL-Out", "BCSR", "JDS",
        "HYB", "SELL", "CRS-Merge",
    ]);
    for name in PICKS {
        let spec = spec_by_name(name).unwrap();
        let a = Arc::new(generate(&spec, common::seed(), scale()));
        let mut row = vec![name.to_string()];
        row.extend(bench_kernels(&a, name, &pool1, &mut json));
        kt.row(row);
    }
    print!("{}", kt.render());

    bench_flops_per_byte(&pool1, &mut json);
    bench_merge_vs_rowpar(&mut json);
    bench_pool_vs_scoped(&mut json);
    common::write_json("micro_hotpath", Json::Arr(json));
}
