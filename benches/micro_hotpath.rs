//! Hot-path microbenchmarks on the host CPU: real wall-clock for the
//! transformations (§2.1), every SpMV kernel (§3) executed through a
//! cached `SpmvPlan`, and the per-call dispatch overhead of the
//! persistent pool vs. spawn-per-call scoped threads. This is the
//! measurement substrate for the performance pass (EXPERIMENTS.md §Perf):
//! run before/after every optimisation.
//!
//! Env knobs: SPMV_AT_SCALE (default 0.05 here — host wallclock, keep it
//! quick), SPMV_AT_REPS (default 7), SPMV_AT_THREADS (pool width for the
//! dispatch-overhead case).

#[path = "common.rs"]
mod common;

use spmv_at::formats::{Csr, SparseMatrix};
use spmv_at::matrixgen::{generate, spec_by_name};
use spmv_at::metrics::{time_median, Json, Table};
use spmv_at::spmv::partition::split_even;
use spmv_at::spmv::pool::{configured_threads, ParPool};
use spmv_at::spmv::{Implementation, SpmvPlan};
use spmv_at::transform;
use std::sync::Arc;

fn reps() -> usize {
    std::env::var("SPMV_AT_REPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if common::quick() { 1 } else { 7 })
}

fn scale() -> f64 {
    std::env::var("SPMV_AT_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if common::quick() { 0.02 } else { 0.05 })
}

/// Representative matrices: near-band (best ELL case), moderate, heavy
/// tail (worst ELL case), big-μ structural.
const PICKS: [&str; 4] = ["chem_master1", "xenon1", "memplus", "sme3Da"];

fn bench_transforms(a: &Csr, name: &str, json: &mut Vec<Json>) -> Vec<String> {
    let r = reps();
    let t_coo_row = time_median(1, r, || {
        std::hint::black_box(transform::crs_to_coo_row(a));
    });
    let t_ccs = time_median(1, r, || {
        std::hint::black_box(transform::crs_to_ccs(a));
    });
    let t_coo_col = time_median(1, r, || {
        std::hint::black_box(transform::crs_to_coo_col(a));
    });
    let t_ell = time_median(1, r, || {
        std::hint::black_box(transform::crs_to_ell(a).ok());
    });
    let t_bcsr = time_median(1, r, || {
        std::hint::black_box(transform::crs_to_bcsr(a, 2, 2).ok());
    });
    json.push(Json::Obj(vec![
        ("matrix".into(), Json::Str(name.into())),
        ("kind".into(), Json::Str("transform".into())),
        ("coo_row".into(), Json::Num(t_coo_row)),
        ("ccs".into(), Json::Num(t_ccs)),
        ("coo_col".into(), Json::Num(t_coo_col)),
        ("ell".into(), Json::Num(t_ell)),
        ("bcsr".into(), Json::Num(t_bcsr)),
    ]));
    vec![
        format!("{:.3}", t_coo_row * 1e3),
        format!("{:.3}", t_ccs * 1e3),
        format!("{:.3}", t_coo_col * 1e3),
        format!("{:.3}", t_ell * 1e3),
        format!("{:.3}", t_bcsr * 1e3),
    ]
}

fn bench_kernels(
    a: &Arc<Csr>,
    name: &str,
    pool: &Arc<ParPool>,
    json: &mut Vec<Json>,
) -> Vec<String> {
    let r = reps();
    let x: Vec<f64> = (0..a.n_cols()).map(|i| 1.0 + (i % 9) as f64 * 0.1).collect();
    let mut y = vec![0.0; a.n_rows()];
    let mut cells = Vec::new();
    let gflops = |t: f64| 2.0 * a.nnz() as f64 / t / 1e9;
    for imp in Implementation::ALL {
        let mut plan = match SpmvPlan::build(a, imp, None, pool.clone()) {
            Ok(p) => p,
            Err(_) => {
                cells.push("-".to_string());
                continue;
            }
        };
        plan.execute(&x, &mut y).unwrap();
        let t = time_median(1, r, || {
            plan.execute(&x, &mut y).unwrap();
        });
        std::hint::black_box(&y);
        cells.push(format!("{:.3}/{:.2}", t * 1e3, gflops(t)));
        json.push(Json::Obj(vec![
            ("matrix".into(), Json::Str(name.into())),
            ("kind".into(), Json::Str("spmv".into())),
            ("imp".into(), Json::Str(imp.name().into())),
            ("seconds".into(), Json::Num(t)),
            ("gflops".into(), Json::Num(gflops(t))),
        ]));
    }
    cells
}

/// The tentpole's headline number: per-call dispatch cost of the
/// persistent pool vs. a fresh `std::thread::scope` fork/join, on a
/// trivially cheap body (sum a range of `x`) so dispatch dominates at
/// small `n` and amortises at large `n`.
fn bench_pool_vs_scoped(json: &mut Vec<Json>) {
    let r = if common::quick() { 3 } else { reps().max(9) };
    let threads = configured_threads().clamp(2, 8);
    let pool = ParPool::new(threads);
    println!(
        "\ndispatch overhead ({threads} threads): spawn-per-call vs persistent pool (us/call):"
    );
    let mut t = Table::new(vec!["n", "scoped", "pool", "speedup"]);
    for n in [1_000usize, 100_000] {
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.001).sin()).collect();
        let ranges = split_even(n, threads);
        let body = |rr: std::ops::Range<usize>| {
            let mut acc = 0.0;
            for i in rr {
                acc += x[i];
            }
            std::hint::black_box(acc);
        };
        let t_scoped = time_median(2, r, || {
            std::thread::scope(|s| {
                for rr in &ranges {
                    let rr = rr.clone();
                    s.spawn(|| body(rr));
                }
            });
        });
        let t_pool = time_median(2, r, || {
            pool.run_chunks(&ranges, |_tid, rr| body(rr));
        });
        t.row(vec![
            n.to_string(),
            format!("{:.2}", t_scoped * 1e6),
            format!("{:.2}", t_pool * 1e6),
            format!("{:.2}x", t_scoped / t_pool.max(1e-12)),
        ]);
        json.push(Json::Obj(vec![
            ("kind".into(), Json::Str("pool_vs_scoped".into())),
            ("n".into(), Json::Num(n as f64)),
            ("threads".into(), Json::Num(threads as f64)),
            ("scoped_seconds".into(), Json::Num(t_scoped)),
            ("pool_seconds".into(), Json::Num(t_pool)),
        ]));
    }
    print!("{}", t.render());
}

fn main() {
    common::banner("micro_hotpath", "host wallclock: transforms + SpMV plans + dispatch overhead");
    let mut json = Vec::new();

    println!("\ntransformations (ms):");
    let mut tt = Table::new(vec!["matrix", "n", "nnz", "COO-Row", "CCS", "COO-Col", "ELL", "BCSR"]);
    for name in PICKS {
        let spec = spec_by_name(name).unwrap();
        let a = generate(&spec, common::seed(), scale());
        let mut row = vec![name.to_string(), a.n_rows().to_string(), a.nnz().to_string()];
        row.extend(bench_transforms(&a, name, &mut json));
        tt.row(row);
    }
    print!("{}", tt.render());

    println!("\nSpMV plans (ms / GFLOP-s), pool size 1:");
    let pool1 = Arc::new(ParPool::new(1));
    let mut kt = Table::new(vec![
        "matrix", "CRS", "CRS-Par", "COO-Col", "COO-Row", "ELL-In", "ELL-Out", "BCSR", "JDS",
        "HYB",
    ]);
    for name in PICKS {
        let spec = spec_by_name(name).unwrap();
        let a = Arc::new(generate(&spec, common::seed(), scale()));
        let mut row = vec![name.to_string()];
        row.extend(bench_kernels(&a, name, &pool1, &mut json));
        kt.row(row);
    }
    print!("{}", kt.render());

    bench_pool_vs_scoped(&mut json);
    common::write_json("micro_hotpath", Json::Arr(json));
}
