//! Hot-path microbenchmarks on the host CPU: real wall-clock for the
//! transformations (§2.1) and every SpMV kernel (§3), per matrix class.
//! This is the measurement substrate for the performance pass
//! (EXPERIMENTS.md §Perf): run before/after every optimisation.
//!
//! Env knobs: SPMV_AT_SCALE (default 0.05 here — host wallclock, keep it
//! quick), SPMV_AT_REPS (default 7).

#[path = "common.rs"]
mod common;

use spmv_at::formats::{Csr, SparseMatrix};
use spmv_at::matrixgen::{generate, spec_by_name};
use spmv_at::metrics::{time_median, Json, Table};
use spmv_at::spmv::{kernels, AnyMatrix, Implementation, Workspace};
use spmv_at::transform;

fn reps() -> usize {
    std::env::var("SPMV_AT_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(7)
}

fn scale() -> f64 {
    std::env::var("SPMV_AT_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.05)
}

/// Representative matrices: near-band (best ELL case), moderate, heavy
/// tail (worst ELL case), big-μ structural.
const PICKS: [&str; 4] = ["chem_master1", "xenon1", "memplus", "sme3Da"];

fn bench_transforms(a: &Csr, name: &str, json: &mut Vec<Json>) -> Vec<String> {
    let r = reps();
    let t_coo_row = time_median(1, r, || {
        std::hint::black_box(transform::crs_to_coo_row(a));
    });
    let t_ccs = time_median(1, r, || {
        std::hint::black_box(transform::crs_to_ccs(a));
    });
    let t_coo_col = time_median(1, r, || {
        std::hint::black_box(transform::crs_to_coo_col(a));
    });
    let t_ell = time_median(1, r, || {
        std::hint::black_box(transform::crs_to_ell(a).ok());
    });
    let t_bcsr = time_median(1, r, || {
        std::hint::black_box(transform::crs_to_bcsr(a, 2, 2).ok());
    });
    json.push(Json::Obj(vec![
        ("matrix".into(), Json::Str(name.into())),
        ("kind".into(), Json::Str("transform".into())),
        ("coo_row".into(), Json::Num(t_coo_row)),
        ("ccs".into(), Json::Num(t_ccs)),
        ("coo_col".into(), Json::Num(t_coo_col)),
        ("ell".into(), Json::Num(t_ell)),
        ("bcsr".into(), Json::Num(t_bcsr)),
    ]));
    vec![
        format!("{:.3}", t_coo_row * 1e3),
        format!("{:.3}", t_ccs * 1e3),
        format!("{:.3}", t_coo_col * 1e3),
        format!("{:.3}", t_ell * 1e3),
        format!("{:.3}", t_bcsr * 1e3),
    ]
}

fn bench_kernels(a: &Csr, name: &str, json: &mut Vec<Json>) -> Vec<String> {
    let r = reps();
    let x: Vec<f64> = (0..a.n_cols()).map(|i| 1.0 + (i % 9) as f64 * 0.1).collect();
    let mut y = vec![0.0; a.n_rows()];
    let mut ws = Workspace::new();
    let mut cells = Vec::new();
    let gflops = |t: f64| 2.0 * a.nnz() as f64 / t / 1e9;
    for imp in Implementation::ALL {
        let m = match AnyMatrix::prepare(a, imp, None) {
            Ok(m) => m,
            Err(_) => {
                cells.push("-".to_string());
                continue;
            }
        };
        kernels::run(imp, &m, &x, &mut y, 1, &mut ws).unwrap();
        let t = time_median(1, r, || {
            kernels::run(imp, &m, &x, &mut y, 1, &mut ws).unwrap();
        });
        std::hint::black_box(&y);
        cells.push(format!("{:.3}/{:.2}", t * 1e3, gflops(t)));
        json.push(Json::Obj(vec![
            ("matrix".into(), Json::Str(name.into())),
            ("kind".into(), Json::Str("spmv".into())),
            ("imp".into(), Json::Str(imp.name().into())),
            ("seconds".into(), Json::Num(t)),
            ("gflops".into(), Json::Num(gflops(t))),
        ]));
    }
    cells
}

fn main() {
    common::banner("micro_hotpath", "host wallclock: transforms + SpMV kernels (1 thread)");
    let mut json = Vec::new();

    println!("\ntransformations (ms):");
    let mut tt = Table::new(vec!["matrix", "n", "nnz", "COO-Row", "CCS", "COO-Col", "ELL", "BCSR"]);
    for name in PICKS {
        let spec = spec_by_name(name).unwrap();
        let a = generate(&spec, common::seed(), scale());
        let mut row = vec![name.to_string(), a.n_rows().to_string(), a.nnz().to_string()];
        row.extend(bench_transforms(&a, name, &mut json));
        tt.row(row);
    }
    print!("{}", tt.render());

    println!("\nSpMV kernels (ms / GFLOP-s), 1 thread:");
    let mut kt = Table::new(vec![
        "matrix", "CRS", "CRS-Par", "COO-Col", "COO-Row", "ELL-In", "ELL-Out", "BCSR", "JDS",
        "HYB",
    ]);
    for name in PICKS {
        let spec = spec_by_name(name).unwrap();
        let a = generate(&spec, common::seed(), scale());
        let mut row = vec![name.to_string()];
        row.extend(bench_kernels(&a, name, &mut json));
        kt.row(row);
    }
    print!("{}", kt.render());
    common::write_json("micro_hotpath", Json::Arr(json));
}
