"""Pytest bootstrap: make the build-time `compile` package importable when
pytest is invoked from the repository root (`pytest python/tests/`)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "python"))
