//! Serving demo: the coordinator as a long-lived service handling
//! concurrent SpMV traffic from multiple clients, with the XLA/Pallas
//! artifact path preferred for ELL-routed matrices — the "library call"
//! deployment shape the paper's AT method is designed for, reported with
//! latency/throughput numbers.
//!
//! Run: `cargo run --release --example serve`

use spmv_at::autotune::online::TuningData;
use spmv_at::coordinator::{Coordinator, CoordinatorConfig, EllExec, Server};
use spmv_at::matrixgen::{banded_circulant, generate, spec_by_name};
use spmv_at::metrics::Stats;
use spmv_at::rng::Rng;
use spmv_at::spmv::Implementation;

fn main() -> anyhow::Result<()> {
    let tuning = TuningData {
        backend: "sim:ES2".into(),
        imp: Implementation::EllRowOuter,
        threads: 1,
        c: 1.0,
        d_star: Some(3.1),
    };
    let mut cfg = CoordinatorConfig::new(tuning);
    cfg.ell_exec = EllExec::XlaPreferred;
    let mut coord = Coordinator::new(cfg);

    // Attach the AOT Pallas artifacts if built.
    let mut _svc = None;
    let art = std::path::PathBuf::from("artifacts");
    if art.join("manifest.tsv").exists() {
        let (svc, handle) = spmv_at::runtime::XlaService::spawn(art)?;
        println!("XLA runtime: {}", handle.platform()?);
        coord = coord.with_xla(handle);
        _svc = Some(svc);
    } else {
        println!("artifacts/ not built — native kernels only (run `make artifacts`)");
    }
    let (_srv, client) = Server::spawn(coord, 128);

    // Three tenants: a bucket-sized band (XLA path), a generated FEM
    // matrix (native ELL), and memplus (stays CRS).
    let mut rng = Rng::new(17);
    client.register("band-xla", banded_circulant(&mut rng, 4096, &[-1, 0, 1, 5]))?;
    client.register("xenon1", generate(&spec_by_name("xenon1").unwrap(), 42, 0.05))?;
    client.register("memplus", generate(&spec_by_name("memplus").unwrap(), 42, 0.1))?;

    // Warm every tenant (triggers the lazy transformations).
    for row in client.stats()? {
        let x = vec![1.0; row.n];
        client.spmv(&row.name, x)?;
    }

    // Concurrent traffic: 3 client threads x 50 requests round-robin.
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for tid in 0..3 {
        let c = client.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<Stats> {
            let mut lat = Stats::new();
            let names = ["band-xla", "xenon1", "memplus"];
            let rows = c.stats()?;
            for k in 0..50 {
                let name = names[(tid + k) % names.len()];
                let n = rows.iter().find(|r| r.name == name).unwrap().n;
                let x = vec![1.0 + k as f64 * 0.01; n];
                let t = std::time::Instant::now();
                let y = c.spmv(name, x)?;
                lat.push(t.elapsed().as_secs_f64());
                std::hint::black_box(&y);
            }
            Ok(lat)
        }));
    }
    let mut all = Stats::new();
    for h in handles {
        let s = h.join().expect("client thread")?;
        for _ in 0..s.count() {
            // merge by moments (approximation fine for the report)
        }
        println!(
            "client: {} requests, latency mean {:.3}ms min {:.3}ms max {:.3}ms",
            s.count(),
            s.mean() * 1e3,
            s.min() * 1e3,
            s.max() * 1e3
        );
        all.push(s.mean());
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served 150 concurrent requests in {wall:.3}s ({:.0} req/s)",
        150.0 / wall
    );

    println!("\nper-tenant state:");
    for row in client.stats()? {
        println!(
            "  {}: n={} nnz={} D={:.2} serving={} calls={} extra_mem={}KB amortized={}",
            row.name,
            row.n,
            row.nnz,
            row.d_mat,
            row.serving,
            row.calls,
            row.extra_bytes / 1024,
            row.amortized
        );
    }
    Ok(())
}
