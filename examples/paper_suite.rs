//! **End-to-end driver** (EXPERIMENTS.md data source): runs the entire
//! paper pipeline on the full synthetic Table-1 suite —
//!
//! 1. regenerate Table 1;
//! 2. offline AT phase on both machine stand-ins → Fig. 8 graphs + D*;
//! 3. Figs. 5–6 headline speedups, Fig. 7 overhead ranges;
//! 4. online phase replayed per matrix inside a *real* workload: a
//!    BiCGStab solve served by the coordinator (with the XLA/Pallas
//!    artifact path exercised for bucket-sized matrices);
//! 5. paper-vs-measured summary table.
//!
//! Run: `cargo run --release --example paper_suite`
//! Env: SPMV_AT_SCALE (default 0.2), SPMV_AT_SEED (default 42).

use spmv_at::autotune::{run_offline, OfflineConfig};
use spmv_at::coordinator::{Coordinator, CoordinatorConfig, EllExec, SolverKind};
use spmv_at::coordinator::Server;
use spmv_at::formats::{Csr, FormatKind, SparseMatrix};
use spmv_at::machine::scalar::ScalarMachine;
use spmv_at::machine::vector::VectorMachine;
use spmv_at::machine::{Backend, SimulatedBackend};
use spmv_at::matrixgen::{generate, make_spd, measure, table1_specs};
use spmv_at::metrics::{Json, Table};
use spmv_at::solver::SolverOptions;
use spmv_at::spmv::Implementation;

fn scale() -> f64 {
    std::env::var("SPMV_AT_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.2)
}

fn seed() -> u64 {
    std::env::var("SPMV_AT_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42)
}

fn main() -> anyhow::Result<()> {
    println!("spmv-at end-to-end paper reproduction (scale {}, seed {})", scale(), seed());
    let mut summary = Vec::new();

    // ---------- 1. Table 1 ----------
    println!("\n### Table 1: synthetic suite");
    let suite: Vec<_> = table1_specs()
        .iter()
        .map(|s| (s.clone(), generate(s, seed(), scale())))
        .collect();
    let mut t = Table::new(vec!["no", "name", "N", "NNZ", "D(pub)", "D(gen)"]);
    for (spec, a) in &suite {
        let m = measure(a);
        t.row(vec![
            spec.no.to_string(),
            spec.name.to_string(),
            m.n.to_string(),
            m.nnz.to_string(),
            format!("{:.2}", spec.d_mat),
            format!("{:.2}", m.d_mat),
        ]);
    }
    print!("{}", t.render());

    // torso1 (no. 3) is excluded from the offline ELL characterisation —
    // its padded ELL overflows memory, exactly as in the paper's §4.2.
    let named: Vec<(String, Csr)> = suite
        .iter()
        .filter(|(s, _)| s.no != 3)
        .map(|(s, a)| (s.name.to_string(), a.clone()))
        .collect();
    let es2 = SimulatedBackend::new(VectorMachine::default());
    let sr = SimulatedBackend::new(ScalarMachine::default());
    let cfg = OfflineConfig::default();

    // ---------- 2. Offline phase / Fig. 8 ----------
    println!("\n### Fig. 8 + offline phase");
    let off_es2 = run_offline(&es2, &named, &cfg)?;
    let off_sr = run_offline(&sr, &named, &cfg)?;
    println!("ES2     D* = {:?}  (paper: 3.10 — every matrix wins)", off_es2.d_star);
    println!("SR16000 D* = {:?}  (paper: ~0.1)", off_sr.d_star);
    summary.push((
        "Fig8 D* (ES2)",
        "3.10".to_string(),
        format!("{:.2}", off_es2.d_star.unwrap_or(f64::NAN)),
    ));
    summary.push((
        "Fig8 D* (SR16000)",
        "~0.1".to_string(),
        format!("{:.2}", off_sr.d_star.unwrap_or(f64::NAN)),
    ));

    // ---------- 3. Figs. 5–7 headlines ----------
    println!("\n### Figs. 5–7 headlines");
    let headline = |backend: &dyn Backend, threads: &[usize]| -> anyhow::Result<(f64, String)> {
        let mut best = (0.0f64, String::new());
        for (spec, a) in &suite {
            if spec.no == 3 {
                continue; // torso1: ELL excluded (memory), as in the paper
            }
            for &th in threads {
                let t_crs = backend.spmv_seconds(a, Implementation::CsrRowPar, th)?;
                for imp in Implementation::AT_CANDIDATES {
                    let sp = t_crs / backend.spmv_seconds(a, imp, th)?;
                    if sp > best.0 {
                        best = (sp, format!("{} / {imp} / {th}t", spec.name));
                    }
                }
            }
        }
        Ok(best)
    };
    let (sp_es2, who_es2) = headline(&es2, &[1, 2, 4, 8])?;
    let (sp_sr, who_sr) = headline(&sr, &[1, 4, 16, 64, 128])?;
    println!("ES2     max SP = {sp_es2:.1}x ({who_es2})   [paper: 151x chem_master1 ELL-inner]");
    println!("SR16000 max SP = {sp_sr:.2}x ({who_sr})   [paper: 2.45x chem_master1 ELL-inner 1t]");
    summary.push(("Fig6 max SP (ES2)", "151x".into(), format!("{sp_es2:.0}x")));
    summary.push(("Fig5 max SP (SR16000)", "2.45x".into(), format!("{sp_sr:.2}x")));

    let tt_range = |backend: &dyn Backend| -> anyhow::Result<(f64, f64)> {
        let (mut lo, mut hi) = (f64::INFINITY, 0.0f64);
        for (spec, a) in &suite {
            if spec.no == 3 {
                continue;
            }
            let t_crs = backend.spmv_seconds(a, Implementation::CsrSeq, 1)?;
            let tt = backend.transform_seconds(a, Implementation::EllRowOuter)? / t_crs;
            lo = lo.min(tt);
            hi = hi.max(tt);
        }
        Ok((lo, hi))
    };
    let (lo_es2, hi_es2) = tt_range(&es2)?;
    let (lo_sr, hi_sr) = tt_range(&sr)?;
    println!("ES2     TT range = {lo_es2:.3} – {hi_es2:.2}   [paper: 0.01 – 0.51]");
    println!("SR16000 TT range = {lo_sr:.2} – {hi_sr:.1}   [paper: up to 20–50]");
    summary.push(("Fig7 TT max (ES2)", "0.51".into(), format!("{hi_es2:.2}")));
    summary.push(("Fig7 TT max (SR16000)", "20-50".into(), format!("{hi_sr:.0}")));

    // ---------- 4. Online phase in a real workload ----------
    println!("\n### Online AT inside a real solve (coordinator + XLA artifacts)");
    let tuning = off_es2.tuning_data();
    let mut ccfg = CoordinatorConfig::new(tuning);
    ccfg.ell_exec = EllExec::XlaPreferred;
    ccfg.threads = 2;
    let mut coord = Coordinator::new(ccfg);
    let mut _xla_svc = None;
    let art = std::path::PathBuf::from("artifacts");
    if art.join("manifest.tsv").exists() {
        match spmv_at::runtime::XlaService::spawn(art) {
            Ok((svc, handle)) => {
                println!("XLA runtime attached: {}", handle.platform().unwrap_or_default());
                coord = coord.with_xla(handle);
                _xla_svc = Some(svc);
            }
            Err(e) => println!("XLA unavailable ({e}); native kernels only"),
        }
    }
    let (_srv, client) = Server::spawn(coord, 64);

    let mut t = Table::new(vec![
        "matrix", "D_mat", "decision", "solver iters", "conv", "serving", "amortized",
    ]);
    let mut decisions = Vec::new();
    for (spec, a) in suite.iter().filter(|(s, _)| [2u32, 6, 12, 14, 21].contains(&s.no)) {
        // SPD-ify for the solver workload (keeps the row-length profile).
        let sys = make_spd(a);
        let n = sys.n_rows();
        let name = spec.name.to_string();
        let st = client.register(&name, sys)?;
        let b = vec![1.0; n];
        let (x, stats) = client.solve(
            &name,
            b,
            SolverKind::BiCgStab,
            SolverOptions { tol: 1e-8, max_iters: 300 },
        )?;
        std::hint::black_box(&x);
        let rows = client.stats()?;
        let row = rows.iter().find(|r| r.name == name).unwrap();
        t.row(vec![
            name.clone(),
            format!("{:.2}", st.d_mat),
            if row.serving == Implementation::CsrSeq { "keep CRS".into() } else { format!("-> {}", row.serving) },
            stats.iterations.to_string(),
            stats.converged.to_string(),
            format!("{:?}", client_format(&client, &name)),
            row.amortized.to_string(),
        ]);
        decisions.push(Json::Obj(vec![
            ("matrix".into(), Json::Str(name)),
            ("d_mat".into(), Json::Num(st.d_mat)),
            ("serving".into(), Json::Str(row.serving.name().into())),
            ("iters".into(), Json::Num(stats.iterations as f64)),
            ("amortized".into(), Json::Bool(row.amortized)),
        ]));
    }
    print!("{}", t.render());

    // ---------- 5. Paper-vs-measured summary ----------
    println!("\n### Paper vs measured (shape comparison)");
    let mut t = Table::new(vec!["metric", "paper", "this repo"]);
    for (m, p, g) in &summary {
        t.row(vec![m.to_string(), p.clone(), g.clone()]);
    }
    print!("{}", t.render());

    let payload = Json::Obj(vec![
        ("scale".into(), Json::Num(scale())),
        (
            "summary".into(),
            Json::Arr(
                summary
                    .iter()
                    .map(|(m, p, g)| {
                        Json::Obj(vec![
                            ("metric".into(), Json::Str(m.to_string())),
                            ("paper".into(), Json::Str(p.clone())),
                            ("measured".into(), Json::Str(g.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("online_workload".into(), Json::Arr(decisions)),
    ]);
    std::fs::create_dir_all("target/bench-results")?;
    std::fs::write("target/bench-results/paper_suite.json", payload.render())?;
    println!("\n[json -> target/bench-results/paper_suite.json]");
    Ok(())
}

/// The format a coordinator-registered matrix is served from (via stats —
/// the client API is channel-based, so we infer from the serving impl).
fn client_format(client: &spmv_at::coordinator::Client, name: &str) -> FormatKind {
    client
        .stats()
        .ok()
        .and_then(|rows| rows.into_iter().find(|r| r.name == name))
        .map(|r| r.serving.required_format())
        .unwrap_or(FormatKind::Csr)
}
