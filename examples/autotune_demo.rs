//! The full §2.2 auto-tuning method, demonstrated end to end on both
//! machine stand-ins:
//!
//! * offline: suite benchmark → D_mat–R_ell graph → D* (per machine);
//! * online: held-out matrices → decision → verification that the
//!   decision matches what exhaustive measurement would have chosen.
//!
//! Run: `cargo run --release --example autotune_demo`

use spmv_at::autotune::{decide, run_offline, OfflineConfig};
use spmv_at::formats::Csr;
use spmv_at::machine::scalar::ScalarMachine;
use spmv_at::machine::vector::VectorMachine;
use spmv_at::machine::{Backend, SimulatedBackend};
use spmv_at::matrixgen::{banded_circulant, generate, table1_specs};
use spmv_at::metrics::Table;
use spmv_at::rng::Rng;
use spmv_at::spmv::Implementation;

fn demo(machine: &str, backend: &dyn Backend) -> anyhow::Result<()> {
    println!("\n================ {machine} ================");
    // Offline on even-numbered matrices; odd ones + synthetics are held out.
    let train: Vec<(String, Csr)> = table1_specs()
        .iter()
        .filter(|s| s.no % 2 == 0)
        .map(|s| (s.name.to_string(), generate(s, 42, 0.03)))
        .collect();
    let cfg = OfflineConfig::default();
    let offline = run_offline(backend, &train, &cfg)?;
    println!("trained on {} matrices -> D* = {:?}", train.len(), offline.d_star);
    let tuning = offline.tuning_data();

    // Held-out evaluation.
    let mut rng = Rng::new(99);
    let mut held: Vec<(String, Csr)> = table1_specs()
        .iter()
        .filter(|s| s.no % 2 == 1 && s.no != 3)
        .map(|s| (s.name.to_string(), generate(s, 1234, 0.03)))
        .collect();
    held.push(("perfect-band".into(), banded_circulant(&mut rng, 20_000, &[-1, 0, 1])));

    let mut t = Table::new(vec!["matrix", "D_mat", "decision", "true R", "correct?"]);
    let mut n_correct = 0;
    for (name, a) in &held {
        let d = decide(a, &tuning);
        // Ground truth: measure this matrix's own R on the backend.
        let t_crs = backend.spmv_seconds(a, Implementation::CsrSeq, cfg.threads)?;
        let t_imp = backend.spmv_seconds(a, cfg.imp, cfg.threads)?;
        let t_trans = backend.transform_seconds(a, cfg.imp)?;
        let r = spmv_at::autotune::Ratios::from_times(t_crs, t_imp, t_trans);
        let truth = r.r >= cfg.c;
        let correct = d.transform == truth;
        n_correct += correct as usize;
        t.row(vec![
            name.clone(),
            format!("{:.3}", d.d_mat),
            if d.transform { format!("ELL ({})", d.chosen) } else { "keep CRS".into() },
            format!("{:.2}", r.r),
            if correct { "yes".into() } else { "NO".to_string() },
        ]);
    }
    print!("{}", t.render());
    println!(
        "online decision accuracy on held-out matrices: {}/{}",
        n_correct,
        held.len()
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("The §2.2 run-time AT method: offline D* extraction + online D_mat decision");
    demo("Earth Simulator 2 (vector model)", &SimulatedBackend::new(VectorMachine::default()))?;
    demo("SR16000/VL1 (scalar model)", &SimulatedBackend::new(ScalarMachine::default()))?;
    println!(
        "\nNote the machine dependence the paper designs for: the same matrices\n\
         transform on the vector machine but stay CRS on the scalar one."
    );
    Ok(())
}
