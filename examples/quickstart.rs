//! Quickstart: the smallest end-to-end use of the library.
//!
//! 1. Build a sparse matrix (CRS).
//! 2. Install a tuning table (here: the simulated ES2 offline phase).
//! 3. Ask the online AT which representation to serve from.
//! 4. Run SpMV through the `OpenATI_DURMV`-style handle.
//!
//! Run: `cargo run --release --example quickstart`

use spmv_at::autotune::atlib::{switches, Durmv};
use spmv_at::autotune::{run_offline, MemoryPolicy, OfflineConfig};
use spmv_at::formats::SparseMatrix;
use spmv_at::machine::vector::VectorMachine;
use spmv_at::machine::SimulatedBackend;
use spmv_at::matrixgen::{banded_circulant, generate, table1_specs};
use spmv_at::rng::Rng;

fn main() -> anyhow::Result<()> {
    // --- offline phase (once per machine install) ---
    let backend = SimulatedBackend::new(VectorMachine::default());
    let suite: Vec<_> = table1_specs()
        .iter()
        .map(|s| (s.name.to_string(), generate(s, 42, 0.02)))
        .collect();
    let offline = run_offline(&backend, &suite, &OfflineConfig::default())?;
    println!(
        "offline phase on {}: D* = {:?}",
        offline.backend, offline.d_star
    );
    let tuning = offline.tuning_data();

    // --- online phase (every library call) ---
    let mut rng = Rng::new(7);
    let a = banded_circulant(&mut rng, 4096, &[-2, -1, 0, 1, 2]);
    println!(
        "input matrix: {}x{}, nnz {}, D_mat {:.3}",
        a.n_rows(),
        a.n_cols(),
        a.nnz(),
        spmv_at::autotune::RowStats::of_csr(&a).d_mat()
    );
    let mut handle = Durmv::new(a, tuning, MemoryPolicy::unlimited(), 2);
    println!("AUTO picks: {}", handle.auto_choice());

    let x = vec![1.0; 4096];
    let mut y = vec![0.0; 4096];
    for i in 0..10 {
        handle.durmv(switches::AUTO, &x, &mut y)?;
        if i == 0 {
            println!(
                "first call transformed in {:.6}s; checksum {:.3}",
                handle.transform_seconds,
                y.iter().sum::<f64>()
            );
        }
    }
    println!(
        "served {} SpMV calls (transformation paid once, amortised across calls)",
        handle.calls
    );
    Ok(())
}
