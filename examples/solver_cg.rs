//! Iterative-solver amortisation — the paper's §2.2 cost argument made
//! observable: a CG solve whose every SpMV routes through the AT
//! coordinator, reporting when the one-off transformation cost is repaid
//! ("2–100 iterations … achievable for many iterative solvers").
//!
//! Run: `cargo run --release --example solver_cg`

use spmv_at::autotune::online::TuningData;
use spmv_at::coordinator::{Coordinator, CoordinatorConfig, Server, SolverKind};
use spmv_at::formats::SparseMatrix;
use spmv_at::matrixgen::{banded_circulant, make_spd};
use spmv_at::rng::Rng;
use spmv_at::solver::SolverOptions;
use spmv_at::spmv::Implementation;

fn main() -> anyhow::Result<()> {
    // A banded SPD system — the FEM-style workload the paper's intro
    // motivates (D_mat ≈ 0 -> the AT transforms to ELL).
    let mut rng = Rng::new(3);
    let a = make_spd(&banded_circulant(&mut rng, 30_000, &[-2, -1, 0, 1, 2]));
    let n = a.n_rows();
    println!(
        "system: n = {}, nnz = {}, D_mat = {:.3}",
        n,
        a.nnz(),
        spmv_at::autotune::RowStats::of_csr(&a).d_mat()
    );

    let tuning = TuningData {
        backend: "sim:ES2".into(),
        imp: Implementation::EllRowOuter,
        threads: 1,
        c: 1.0,
        d_star: Some(3.1),
    };
    let (_srv, client) = Server::spawn(
        Coordinator::new(CoordinatorConfig::new(tuning)),
        32,
    );
    client.register("fem", a)?;

    let b = vec![1.0; n];
    let t0 = std::time::Instant::now();
    let (x, stats) = client.solve(
        "fem",
        b,
        SolverKind::Cg,
        SolverOptions { tol: 1e-10, max_iters: 500 },
    )?;
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "CG: {} iterations, converged = {}, residual = {:.3e}, wall = {:.3}s",
        stats.iterations, stats.converged, stats.residual, wall
    );
    println!("|x| = {:.6e}", x.iter().map(|v| v * v).sum::<f64>().sqrt());

    for row in client.stats()? {
        println!(
            "coordinator: serving = {}, calls = {}, transformed calls = {}, \
             t_trans = {:.6}s, amortized = {}, calls-to-break-even ≈ {}",
            row.serving,
            row.calls,
            row.transformed_calls,
            row.t_trans,
            row.amortized,
            if row.amortized { "done".to_string() } else { "pending".into() }
        );
        assert_eq!(
            row.calls as usize, stats.spmv_calls,
            "every solver SpMV must route through the coordinator"
        );
    }
    Ok(())
}
